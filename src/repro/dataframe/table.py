"""Columnar table with static capacity — the TPU adaptation of Arrow partitions.

The paper's partitions are Arrow tables whose length varies per worker.  XLA
programs need static shapes, so a partition here is a set of fixed-capacity
column arrays plus a traced ``row_count``; rows ``[0, row_count)`` are valid
and **compacted to the front** (every operator maintains this invariant).
This mirrors Arrow's data/validity buffer split with the validity buffer
degenerated to a prefix length, which is what the sort-based local operators
naturally produce.

``Table`` is a pytree, so it flows through ``jax.jit`` / ``jax.shard_map``
directly.  Inside a shard_map region ``row_count`` has shape ``()``; the
driver-side distributed holder (``core.env``) stacks one ``Table`` per shard.

String columns never appear here: they are dictionary-encoded at ingest
(``dataframe.schema``) and the device sees only their int32 *code* arrays —
the dictionaries are sorted, so code order equals string order and every
operator below runs unchanged.  The dictionaries themselves travel on the
driver-side holders (``DistTable.dictionaries`` /
``SpillTable.dictionaries``); see ``docs/data_model.md``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel ordering values used to push invalid rows to the end of sorts.
_INT_SENTINEL = np.iinfo(np.int32).max
_FLOAT_SENTINEL = np.inf


def _sentinel_for(dtype) -> jnp.ndarray:
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(_FLOAT_SENTINEL, dtype)
    return jnp.asarray(jnp.iinfo(dtype).max, dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Table:
    """One partition: dict of (capacity,)-shaped columns + valid row count."""

    columns: Dict[str, jax.Array]
    row_count: jax.Array  # int32 scalar (traced)

    # ------------------------------------------------------------------ #
    # pytree protocol
    # ------------------------------------------------------------------ #
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        children = tuple(self.columns[n] for n in names) + (self.row_count,)
        return children, names

    @classmethod
    def tree_unflatten(cls, names, children):
        cols = dict(zip(names, children[:-1]))
        return cls(columns=cols, row_count=children[-1])

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @classmethod
    def from_arrays(cls, data: Mapping[str, jax.Array], capacity: Optional[int] = None,
                    row_count: Optional[jax.Array] = None) -> "Table":
        """Build a table from equal-length dense arrays, padding to capacity."""
        for k, v in data.items():
            if isinstance(v, np.ndarray) and v.dtype.kind in ("O", "U", "S"):
                raise TypeError(
                    f"column {k!r} holds strings; device Tables carry int32 "
                    f"dictionary codes — encode driver-side with "
                    f"dataframe.schema.encode_strings (or ingest through "
                    f"DistTable.from_numpy / repro.df)")
        data = {k: jnp.asarray(v) for k, v in data.items()}
        n = next(iter(data.values())).shape[0]
        for k, v in data.items():
            if v.shape[0] != n:
                raise ValueError(f"column {k!r} length {v.shape[0]} != {n}")
        capacity = capacity or n
        if capacity < n:
            raise ValueError(f"capacity {capacity} < rows {n}")
        cols = {}
        for k, v in data.items():
            pad = capacity - n
            if pad:
                v = jnp.concatenate([v, jnp.zeros((pad,) + v.shape[1:], v.dtype)])
            cols[k] = v
        rc = jnp.asarray(n if row_count is None else row_count, jnp.int32)
        return cls(cols, rc)

    @classmethod
    def empty_like(cls, other: "Table", capacity: Optional[int] = None) -> "Table":
        cap = capacity or other.capacity
        cols = {k: jnp.zeros((cap,) + v.shape[1:], v.dtype) for k, v in other.columns.items()}
        return cls(cols, jnp.asarray(0, jnp.int32))

    # ------------------------------------------------------------------ #
    # basic properties
    # ------------------------------------------------------------------ #
    @property
    def capacity(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    @property
    def column_names(self) -> Tuple[str, ...]:
        return tuple(sorted(self.columns))

    def valid_mask(self) -> jax.Array:
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.row_count

    def col(self, name: str) -> jax.Array:
        return self.columns[name]

    # ------------------------------------------------------------------ #
    # structural ops (no communication)
    # ------------------------------------------------------------------ #
    def select(self, names: Sequence[str]) -> "Table":
        return Table({n: self.columns[n] for n in names}, self.row_count)

    def with_column(self, name: str, values: jax.Array) -> "Table":
        cols = dict(self.columns)
        cols[name] = values
        return Table(cols, self.row_count)

    def rename(self, mapping: Mapping[str, str]) -> "Table":
        cols = {mapping.get(k, k): v for k, v in self.columns.items()}
        return Table(cols, self.row_count)

    def take(self, idx: jax.Array, new_count: jax.Array) -> "Table":
        """Gather rows by index (invalid slots may point anywhere)."""
        cols = {k: jnp.take(v, idx, axis=0) for k, v in self.columns.items()}
        return Table(cols, jnp.asarray(new_count, jnp.int32))

    def mask_padding(self) -> "Table":
        """Zero out the padding region (canonicalises sentinel garbage)."""
        m = self.valid_mask()
        cols = {}
        for k, v in self.columns.items():
            mm = m.reshape((-1,) + (1,) * (v.ndim - 1))
            cols[k] = jnp.where(mm, v, jnp.zeros((), v.dtype))
        return Table(cols, self.row_count)

    # ------------------------------------------------------------------ #
    # host-side conversion (not jittable)
    # ------------------------------------------------------------------ #
    def to_numpy(self) -> Dict[str, np.ndarray]:
        n = int(self.row_count)
        return {k: np.asarray(v)[:n] for k, v in self.columns.items()}


def concat_tables(tables: Sequence[Table], capacity: Optional[int] = None) -> Table:
    """Concatenate partitions (compacted), padding to ``capacity``."""
    names = tables[0].column_names
    total_cap = sum(t.capacity for t in tables)
    capacity = capacity or total_cap
    cols = {}
    counts = jnp.stack([t.row_count for t in tables])
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    total = jnp.sum(counts)
    for name in names:
        stacked = jnp.concatenate([t.columns[name] for t in tables], axis=0)
        # compaction: position of row i of table t is offsets[t] + i
        out = jnp.zeros((capacity,) + stacked.shape[1:], stacked.dtype)
        pos = 0
        for t_idx, t in enumerate(tables):
            idx = jnp.arange(t.capacity, dtype=jnp.int32)
            dest = offsets[t_idx] + idx
            valid = idx < counts[t_idx]
            dest = jnp.where(valid, dest, capacity)  # out-of-range drops
            out = out.at[dest].set(t.columns[name][idx], mode="drop")
            pos += t.capacity
        cols[name] = out
    return Table(cols, jnp.asarray(total, jnp.int32))

"""Dictionary-encoded string columns: the driver-side half of the dtype
system (see ``docs/data_model.md``).

The paper's Cylon partitions are Arrow tables with heterogeneous typed
columns; XLA programs only move fixed-width numbers.  The adaptation is
Arrow's dictionary encoding with one extra invariant: every dictionary is
**lexicographically sorted**, so the int32 codes are *order-isomorphic* to
the strings they stand for —

    sort / min / max / range-partition on codes  ==  the same on strings,
    code equality                                ==  string equality
                                                     (same dictionary).

That single invariant is what lets every device-side operator (sort-based
join/groupby, sample-sort, radix shuffle, the murmur hash) run on plain
int32 arrays with **zero** string-awareness.  The string side of the world
lives entirely on the driver:

* ``encode_strings``    — host ingest: values -> (codes, sorted dictionary),
* ``decode_codes``      — host egress: codes -> numpy unicode array,
* ``recode_mapping``    — old-dictionary codes -> new-dictionary codes
                          (a static int32 gather table; the planner bakes it
                          into the compiled program as a ``recode`` node
                          when two join inputs' dictionaries differ),
* ``merge_dictionaries``— sorted union (the recode target),
* ``lower_expr``        — rewrite string literals inside ``repro.expr``
                          trees into code comparisons against a column's
                          dictionary (``col("s") < "oak"`` becomes an int32
                          compare via ``searchsorted``),
* ``expr_dictionary``   — which dictionary (if any) an expression's output
                          codes belong to.

Dictionaries are plain tuples of python str, carried by the driver-side
table holders (``core.DistTable.dictionaries`` /
``core.SpillTable.dictionaries``) and by every annotated logical plan node
(``LogicalNode.dicts``); the device-side ``dataframe.Table`` never sees
them.
"""

from __future__ import annotations

from typing import Dict, Mapping, Optional, Tuple

import numpy as np

from ..expr import BinOp, Col, Expr, FillNull, IsNull, Lit, OpaqueExpr, \
    UnaryOp, _ARITH, _BOOL, _COMPARE

__all__ = [
    "Dictionary", "is_string_array", "encode_strings", "decode_codes",
    "encode_columns", "decode_columns", "merge_dictionaries",
    "recode_mapping", "lower_expr", "expr_dictionary", "DictTypeError",
]

#: a column dictionary: lexicographically sorted, duplicate-free strings
Dictionary = Tuple[str, ...]

#: device dtype of dictionary codes
CODE_DTYPE = np.int32


class DictTypeError(TypeError):
    """An operation is not defined on dictionary-encoded string columns."""


def is_string_array(arr: np.ndarray) -> bool:
    """True for numpy arrays holding strings (object / unicode / bytes)."""
    return arr.dtype.kind in ("O", "U", "S")


def _as_str_array(arr: np.ndarray, name: str = "column") -> np.ndarray:
    """Validate an object array holds only strings; normalize to unicode."""
    if arr.dtype.kind == "O":
        for v in arr:
            if not isinstance(v, str):
                raise TypeError(
                    f"{name} mixes strings with {type(v).__name__}; "
                    f"dictionary encoding needs all-string values")
        return arr.astype(str) if arr.size else arr.astype("U1")
    if arr.dtype.kind == "S":
        return arr.astype(str)
    return arr


def encode_strings(arr: np.ndarray, name: str = "column"
                   ) -> Tuple[np.ndarray, Dictionary]:
    """Host-side ingest: string values -> (int32 codes, sorted dictionary).

    ``np.unique`` returns the *sorted* distinct values, so ``codes`` are
    order-isomorphic to the strings (the module-level invariant).
    """
    arr = _as_str_array(np.asarray(arr), name)
    if arr.size == 0:
        return np.zeros((0,), CODE_DTYPE), ()
    values, codes = np.unique(arr, return_inverse=True)
    return codes.astype(CODE_DTYPE), tuple(str(v) for v in values)


def dictionary_of(arr: np.ndarray) -> Dictionary:
    """Sorted dictionary of a string array WITHOUT computing codes.

    Used by the planner catalog, which only needs the dictionary — skips
    ``return_inverse`` and the per-element validation of
    ``encode_strings`` (ingest re-validates and must yield the identical
    dictionary, since both sort the same distinct values).
    """
    arr = np.asarray(arr)
    if arr.size == 0:
        return ()
    if arr.dtype.kind in ("O", "S"):
        arr = arr.astype(str)
    return tuple(str(v) for v in np.unique(arr))


def decode_codes(codes: np.ndarray, dictionary: Dictionary) -> np.ndarray:
    """Host-side egress: int32 codes -> numpy unicode array.

    Decode runs on valid rows only (padding is sliced off before it), so
    an out-of-range code means upstream corruption — raise loudly instead
    of silently returning some dictionary entry.
    """
    codes = np.asarray(codes)
    if codes.size == 0:
        return np.zeros(codes.shape, "U1")
    if (not dictionary or int(codes.min()) < 0
            or int(codes.max()) >= len(dictionary)):
        raise ValueError(
            f"dictionary codes out of range [0, {len(dictionary)}): "
            f"min={int(codes.min()) if codes.size else 0}, "
            f"max={int(codes.max()) if codes.size else 0} — the table's "
            f"dictionary does not match its code column")
    return np.asarray(dictionary)[codes]


def encode_columns(data: Mapping[str, np.ndarray]
                   ) -> Tuple[Dict[str, np.ndarray], Dict[str, Dictionary]]:
    """Encode every string column of a host column dict; numeric columns
    pass through.  Returns ``(columns, dictionaries)``."""
    cols: Dict[str, np.ndarray] = {}
    dicts: Dict[str, Dictionary] = {}
    for name, arr in data.items():
        arr = np.asarray(arr)
        if is_string_array(arr):
            cols[name], dicts[name] = encode_strings(arr, name=repr(name))
        else:
            cols[name] = arr
    return cols, dicts


def decode_columns(cols: Mapping[str, np.ndarray],
                   dicts: Mapping[str, Dictionary]) -> Dict[str, np.ndarray]:
    """Decode the dictionary-encoded columns of a host column dict."""
    return {name: decode_codes(v, dicts[name]) if name in dicts else v
            for name, v in cols.items()}


def merge_dictionaries(a: Dictionary, b: Dictionary) -> Dictionary:
    """Sorted union — the recode target when two inputs disagree."""
    return tuple(sorted(set(a) | set(b)))


def recode_mapping(old: Dictionary, new: Dictionary) -> np.ndarray:
    """Static gather table: ``new_codes = mapping[old_codes]``.

    Every ``old`` entry must exist in ``new`` (``new`` is a superset by
    construction).  Never empty — a length-1 zero table keeps the device
    gather well-defined for all-padding columns.
    """
    if not old:
        return np.zeros((1,), CODE_DTYPE)
    missing = sorted(set(old) - set(new))
    if missing:
        raise ValueError(f"recode target is missing entries {missing[:5]}")
    pos = np.searchsorted(np.asarray(new), np.asarray(old))
    return pos.astype(CODE_DTYPE)


# ---------------------------------------------------------------------- #
# Expression lowering: string literals -> code comparisons
# ---------------------------------------------------------------------- #
class _StrLit:
    """Marker meta for a raw string literal awaiting a dictionary context."""

    __slots__ = ("value",)

    def __init__(self, value: str):
        self.value = value


def _code_lit(v: int) -> Lit:
    # a plain python int: weakly typed, so comparisons keep the code
    # column's int32 dtype (and EXPLAIN renders `s >= 4`, not a numpy repr)
    return Lit(int(v))


_UNSUPPORTED = ("only == != < <= > >= comparisons against string literals "
                "or same-dictionary columns are supported on "
                "dictionary-encoded string columns (plus join/groupby/sort "
                "keys and min/max/count aggregates)")


def _lower_compare(op: str, cexpr: Expr, d: Dictionary, s: str,
                   swap: bool) -> Expr:
    """Rewrite ``col <op> "s"`` into an int32 code comparison.

    ``d`` is sorted, so with ``lo/hi = searchsorted(d, s, left/right)``:
    ``x < s``  ⇔ ``code < lo``;   ``x <= s`` ⇔ ``code < hi``;
    ``x > s``  ⇔ ``code >= hi``;  ``x >= s`` ⇔ ``code >= lo``;
    ``x == s`` ⇔ ``code == lo`` when present, else always-False (``-1``).
    ``swap`` mirrors for ``"s" <op> col``.
    """
    if swap:
        op = {"<": ">", "<=": ">=", ">": "<", ">=": "<="}.get(op, op)
    arr = np.asarray(d) if d else np.zeros((0,), "U1")
    lo = int(np.searchsorted(arr, s, side="left"))
    hi = int(np.searchsorted(arr, s, side="right"))
    present = hi > lo
    if op == "==":
        return BinOp("==", cexpr, _code_lit(lo if present else -1))
    if op == "!=":
        return BinOp("!=", cexpr, _code_lit(lo if present else -1))
    if op == "<":
        return BinOp("<", cexpr, _code_lit(lo))
    if op == "<=":
        return BinOp("<", cexpr, _code_lit(hi))
    if op == ">":
        return BinOp(">=", cexpr, _code_lit(hi))
    if op == ">=":
        return BinOp(">=", cexpr, _code_lit(lo))
    raise AssertionError(op)


def _lower(e: Expr, dicts: Mapping[str, Dictionary]):
    """Recursive lowering: returns ``(expr, meta)`` where meta is ``None``
    (numeric value), a ``Dictionary`` (value is codes in that dictionary),
    or ``_StrLit`` (raw string literal, resolved by an enclosing compare)."""
    if isinstance(e, Col):
        return e, dicts.get(e.name)
    if isinstance(e, Lit):
        if isinstance(e.value, (str, np.str_)):
            return e, _StrLit(str(e.value))
        return e, None
    if isinstance(e, UnaryOp):
        op, meta = _lower(e.operand, dicts)
        if meta is not None:
            raise DictTypeError(
                f"unary {e.op!r} on a dictionary-encoded string value "
                f"({e!r}): {_UNSUPPORTED}")
        return UnaryOp(e.op, op), None
    if isinstance(e, IsNull):
        # null-ness lives in the validity mask, not the codes: defined for
        # every column type, always a plain boolean result
        op, meta = _lower(e.operand, dicts)
        if isinstance(meta, _StrLit):
            op = _code_lit(0)          # a literal is never null
        return IsNull(op), None
    if isinstance(e, FillNull):
        op, om = _lower(e.operand, dicts)
        fl, fm = _lower(e.fill, dicts)
        if isinstance(om, _StrLit):    # literal operand: never null
            return op, om
        if isinstance(om, tuple):
            if isinstance(fm, _StrLit):
                s = fm.value
                arr = np.asarray(om) if om else np.zeros((0,), "U1")
                lo = int(np.searchsorted(arr, s, side="left"))
                if not (lo < len(om) and om[lo] == s):
                    raise DictTypeError(
                        f"fill_null value {s!r} is not in the column's "
                        f"dictionary ({e!r}); fill with an existing value "
                        f"or extend the dictionary at ingest")
                return FillNull(op, _code_lit(lo)), om
            if isinstance(fm, tuple):
                if fm != om:
                    raise DictTypeError(
                        f"fill_null fill column uses a different dictionary "
                        f"than its operand ({e!r}); join/merge them first "
                        f"so the planner recodes to a shared dictionary")
                return FillNull(op, fl), om
            raise DictTypeError(
                f"cannot fill_null a dictionary-encoded string column "
                f"with a numeric value ({e!r})")
        if isinstance(fm, (tuple, _StrLit)):
            raise DictTypeError(
                f"cannot fill_null a numeric column with a string value "
                f"({e!r})")
        return FillNull(op, fl), None
    if isinstance(e, OpaqueExpr):
        cols = e.columns()
        touched = sorted(dicts if cols is None
                         else set(cols) & set(dicts))
        if touched:
            raise DictTypeError(
                f"opaque callable {e!r} touches dictionary-encoded "
                f"column(s) {touched}; rewrite it as a typed expression "
                f"so string literals can be lowered against the dictionary")
        return e, None
    if isinstance(e, BinOp):
        l, lm = _lower(e.left, dicts)
        r, rm = _lower(e.right, dicts)
        if lm is None and rm is None:
            return BinOp(e.op, l, r), None
        if e.op in _COMPARE:
            if isinstance(lm, tuple) and isinstance(rm, _StrLit):
                return _lower_compare(e.op, l, lm, rm.value, swap=False), None
            if isinstance(lm, _StrLit) and isinstance(rm, tuple):
                return _lower_compare(e.op, r, rm, lm.value, swap=True), None
            if isinstance(lm, tuple) and isinstance(rm, tuple):
                if lm != rm:
                    raise DictTypeError(
                        f"cannot compare dictionary-encoded columns with "
                        f"different dictionaries ({e!r}); join/merge them "
                        f"first so the planner recodes to a shared "
                        f"dictionary")
                return BinOp(e.op, l, r), None
            raise DictTypeError(
                f"cannot compare a dictionary-encoded string value with a "
                f"numeric value ({e!r})")
        kind = "arithmetic" if e.op in _ARITH else \
            "boolean" if e.op in _BOOL else "binary"
        raise DictTypeError(
            f"{kind} {e.op!r} on a dictionary-encoded string value "
            f"({e!r}): {_UNSUPPORTED}")
    raise TypeError(f"cannot lower {type(e).__name__}")


def lower_expr(e: Expr, dicts: Mapping[str, Dictionary]
               ) -> Tuple[Expr, Optional[Dictionary]]:
    """Lower string literals in ``e`` against the input's per-column
    ``dicts``; returns ``(lowered expr, output dictionary or None)``.

    A bare string literal becomes a constant column over the singleton
    dictionary ``(s,)`` (code 0).  Raises ``DictTypeError`` for operations
    with no dictionary-code semantics (arithmetic on strings, mixed-type
    comparisons, cross-dictionary column comparisons).
    """
    out, meta = _lower(e, dicts)
    if isinstance(meta, _StrLit):
        return _code_lit(0), (meta.value,)
    return out, meta


def expr_dictionary(e: Expr, dicts: Mapping[str, Dictionary]
                    ) -> Optional[Dictionary]:
    """The dictionary an expression's output codes belong to, or ``None``
    for numeric results.  Structural only (no validation): ``col(c)``
    passthroughs keep ``c``'s dictionary, bare string literals get the
    singleton dictionary — everything else is numeric.
    """
    if isinstance(e, Col):
        return dicts.get(e.name)
    if isinstance(e, Lit) and isinstance(e.value, (str, np.str_)):
        return (str(e.value),)
    if isinstance(e, FillNull):
        return expr_dictionary(e.operand, dicts)
    return None

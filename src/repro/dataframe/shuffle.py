"""Distributed shuffle: capacity-based all-to-all (the paper's core comm op).

MPI AllToAllv sends exact per-destination byte counts; XLA collectives are
static-shape.  The adaptation (DESIGN.md §2) is the MoE-capacity idiom:

  1. hash keys -> destination rank (or take explicit destinations),
  2. counts exchange (tiny all_to_all) for observability + receive counts,
  3. rows are bucketed into a ``(p, bucket_capacity)`` send buffer
     (overflow rows are dropped and *counted* —
     ``ShuffleStats.send_dropped``),
  4. data all_to_all per packed buffer (4-byte columns are bitcast and
     packed into a single ``(p, cap, ncols)`` uint32 buffer so the shuffle
     issues one large collective — the "fewer, larger messages"
     optimization the paper attributes to tuned MPI algorithms), optionally
     *chunked* along the capacity axis (``a2a_chunks``) into k pipelined
     collectives (``Communicator.all_to_all_chunked``),
  5. receive-side compaction back to a fixed-capacity ``Table``.

Two bucketize/compaction implementations (``impl``):

* ``"radix"`` (default) — sort-free hot path.  Send side: the
  ``kernels.radix_partition`` (rank-in-bucket, histogram) pair drives a
  direct scatter of the u32-packed rows — each row is touched exactly once,
  no ``argsort``/gather.  Receive side: exclusive prefix sums over
  ``recv_counts`` give every received row its output slot, so compaction
  is a single O(n) masked scatter.  Pallas kernel on TPU, the segment-
  cumsum XLA path elsewhere.
* ``"sorted"`` — the original two-``argsort`` implementation
  (O(n log n) send-side bucketize + O(n log n) receive-side compaction),
  kept as the parity oracle and benchmark baseline.

Both produce **bit-identical** outputs (same rows in the same slots): the
radix ranks are stable, so overflow drops the same rows, and the prefix-sum
compaction enumerates valid rows in the same (source-rank, slot) order as
the stable sort did.

The sample-based repartitioner (``sort.py`` splitters, paper §VI future
work) exists to keep bucket skew bounded so capacity factors stay small.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..comm import Communicator
from ..kernels import radix_partition
from .ops_local import hash_columns
from .table import Table


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShuffleStats:
    """Per-rank observability for one shuffle (traced arrays + static tags)."""

    sent_counts: jax.Array   # (p,) rows sent to each rank (post-capacity)
    recv_counts: jax.Array   # (p,) rows received from each rank
    send_dropped: jax.Array  # () rows dropped by send-bucket capacity
    recv_dropped: jax.Array  # () rows dropped by receive-table capacity
    shuffle_impl: str = "radix"   # static: which bucketize path ran
    a2a_chunks: int = 1           # static: all-to-all pipeline depth

    def tree_flatten(self):
        return (self.sent_counts, self.recv_counts, self.send_dropped,
                self.recv_dropped), (self.shuffle_impl, self.a2a_chunks)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def default_bucket_capacity(capacity: int, p: int, factor: float = 2.0) -> int:
    """Per-destination bucket size: balanced share × skew headroom, 8-aligned."""
    return max(8, _round_up(int(-(-capacity // p) * factor), 8))


def _pack_u32(cols: Dict[str, jax.Array], names) -> jax.Array:
    """Bitcast 4-byte columns to uint32 and stack: (cap,) xN -> (cap, N).

    Bool columns (validity masks) widen to uint32 lanes: wasteful per bit,
    but it keeps the whole row — masks included — in the one large packed
    collective instead of issuing a separate small all_to_all per mask."""
    parts = []
    for n in names:
        v = cols[n]
        if v.dtype == jnp.float32:
            v = jax.lax.bitcast_convert_type(v, jnp.uint32)
        elif v.dtype == jnp.bool_:
            v = v.astype(jnp.uint32)
        elif v.dtype in (jnp.int32, jnp.uint32):
            v = v.view(jnp.uint32) if hasattr(v, "view") else jax.lax.bitcast_convert_type(v, jnp.uint32)
        else:
            raise TypeError(n)
        parts.append(v)
    return jnp.stack(parts, axis=-1)


def _unpack_u32(buf: jax.Array, names, dtypes) -> Dict[str, jax.Array]:
    out = {}
    for i, n in enumerate(names):
        v = buf[..., i]
        if dtypes[n] == jnp.float32:
            v = jax.lax.bitcast_convert_type(v, jnp.float32)
        else:
            v = v.astype(dtypes[n])
        out[n] = v
    return out


#: (label, rank) pairs that already warned since the last query start —
#: the morsel executor runs one callback per shuffle PER MORSEL per rank,
#: so without dedupe a streaming run spams hundreds of identical warnings.
#: The executors reset this at query start; totals stay exactly attributed
#: via the end-of-query ``describe_drops`` summary.
_warned_overflow: set = set()


def reset_overflow_warnings() -> None:
    """Start a fresh warn-once-per-(op label, rank) window (called by the
    executors at query start)."""
    _warned_overflow.clear()


def _overflow_warn(rank, send_dropped, recv_dropped, label=""):
    """Host-side overflow check (``debug_overflow=True``): warn, don't drop
    silently — and say *which* op and rank overflowed.  Runs as a debug
    callback so it works under jit/shard_map (one callback per rank);
    deduplicated to once per (op label, rank) per query."""
    import warnings
    sd, rd = int(send_dropped), int(recv_dropped)
    if sd or rd:
        key = (label or "shuffle", int(rank))
        if key in _warned_overflow:
            return
        _warned_overflow.add(key)
        where = f"{key[0]} @ rank {key[1]}"
        warnings.warn(
            f"{where} dropped rows: send_dropped={sd} recv_dropped={rd} "
            f"(raise bucket_capacity / out_capacity or capacity_factor; "
            f"per-query totals are attributed in the end-of-query "
            f"summary)",
            RuntimeWarning, stacklevel=2)


def shuffle(
    table: Table,
    comm: Communicator,
    key_cols: Optional[Sequence[str]] = None,
    dest: Optional[jax.Array] = None,
    bucket_capacity: Optional[int] = None,
    out_capacity: Optional[int] = None,
    capacity_factor: float = 2.0,
    pack: bool = True,
    impl: str = "radix",
    a2a_chunks: int = 1,
    debug_overflow: bool = False,
    label: str = "",
) -> Tuple[Table, ShuffleStats]:
    """Repartition rows across the comm axis by key hash or explicit dest.

    Must run inside a shard_map region over ``comm.axis``.  ``impl`` selects
    the sort-free ``"radix"`` hot path or the ``"sorted"`` baseline (module
    docstring); ``a2a_chunks`` splits the data collective into k pipelined
    pieces; ``debug_overflow`` emits a host-side warning whenever capacity
    pressure drops rows (they are always *counted* in the stats).
    ``label`` is a static plan-level tag (e.g. ``"join(k):left"``) used only
    to attribute overflow warnings — it never affects the computation.
    """
    if impl not in ("radix", "sorted"):
        raise ValueError(f"unknown shuffle impl {impl!r}")
    p = comm.size()
    cap = table.capacity
    bucket_cap = bucket_capacity or default_bucket_capacity(cap, p, capacity_factor)
    out_cap = out_capacity or cap
    valid = table.valid_mask()

    if dest is None:
        if not key_cols:
            raise ValueError("need key_cols or dest")
        h = hash_columns(table, key_cols)
        dest = (h % jnp.uint32(p)).astype(jnp.int32)
    dest = jnp.where(valid, dest, p)  # invalid rows -> overflow bin p

    # --- bucketize: per-row send-buffer slot ----------------------------- #
    if impl == "radix":
        # sort-free: stable rank within destination bucket + histogram in
        # one kernel pass (Pallas on TPU, segment-cumsum XLA path elsewhere)
        ranks, hist = radix_partition(dest, p + 1)
        raw_counts = hist[:p]
        row_rank = ranks
        row_dest = dest
        order = None
    else:
        # the PR-1 two-argsort baseline: stable sort by destination, rank =
        # position - bucket start (kept as oracle + benchmark column)
        order = jnp.argsort(dest, stable=True)
        sorted_dest = jnp.take(dest, order)
        pos = jnp.arange(cap, dtype=jnp.int32)
        bucket_start = jnp.searchsorted(sorted_dest, sorted_dest, side="left")
        row_rank = pos - bucket_start
        row_dest = sorted_dest
        raw_counts = jax.ops.segment_sum(
            jnp.ones((cap,), jnp.int32), dest, num_segments=p + 1)[:p]

    sent_counts = jnp.minimum(raw_counts, bucket_cap)
    send_dropped = jnp.sum(raw_counts - sent_counts)

    in_bucket = (row_dest < p) & (row_rank < bucket_cap)
    slot = jnp.where(in_bucket, row_dest * bucket_cap + row_rank,
                     p * bucket_cap)  # out-of-range -> dropped by mode="drop"

    names = table.column_names
    dtypes = {n: table.columns[n].dtype for n in names}
    four_byte = [n for n in names
                 if dtypes[n] in (jnp.float32, jnp.int32, jnp.uint32,
                                  jnp.bool_)
                 and table.columns[n].ndim == 1]
    packables = four_byte if pack else []
    singles = [n for n in names if n not in packables]

    recv_cols: Dict[str, jax.Array] = {}

    def _scatter(col: jax.Array) -> jax.Array:
        # radix: direct scatter by original row (each row touched once);
        # sorted: rows were gathered into destination order first.
        buf = jnp.zeros((p * bucket_cap,) + col.shape[1:], col.dtype)
        return buf.at[slot].set(col, mode="drop")

    if packables:
        packed = _pack_u32(table.columns, packables)          # (cap, N)
        if order is not None:
            packed = jnp.take(packed, order, axis=0)
        buf = _scatter(packed).reshape(p, bucket_cap, len(packables))
        got = comm.all_to_all_chunked(buf, chunks=a2a_chunks)
        recv_cols.update(_unpack_u32(
            got.reshape(p * bucket_cap, len(packables)), packables, dtypes))
    for n in singles:
        col = table.columns[n]
        if order is not None:
            col = jnp.take(col, order, axis=0)
        buf = _scatter(col).reshape((p, bucket_cap) + col.shape[1:])
        got = comm.all_to_all_chunked(buf, chunks=a2a_chunks)
        recv_cols[n] = got.reshape((p * bucket_cap,) + col.shape[1:])

    recv_counts = comm.exchange_counts(sent_counts)
    total_recv = jnp.sum(recv_counts)
    new_count = jnp.minimum(total_recv, out_cap).astype(jnp.int32)

    # --- receive-side compaction ----------------------------------------- #
    ridx = jnp.arange(p * bucket_cap, dtype=jnp.int32)
    blk, q = ridx // bucket_cap, ridx % bucket_cap
    r_valid = q < jnp.take(recv_counts, blk)
    out_size = min(p * bucket_cap, out_cap)  # what the argsort slice produced
    if impl == "radix":
        # sort-free: slot of a valid row (blk, q) is its rank in the
        # (source-rank, slot) enumeration = exclusive prefix over recv_counts
        offsets = jnp.cumsum(recv_counts) - recv_counts     # exclusive
        out_pos = jnp.where(r_valid, jnp.take(offsets, blk) + q, out_size)
        out_cols = {}
        for n, v in recv_cols.items():
            out = jnp.zeros((out_size,) + v.shape[1:], v.dtype)
            out_cols[n] = out.at[out_pos].set(v, mode="drop")
    else:
        order2 = jnp.argsort(jnp.where(r_valid, 0, 1), stable=True)[:out_cap]
        out_cols = {n: jnp.take(v, order2, axis=0) for n, v in recv_cols.items()}

    recv_dropped = jnp.maximum(total_recv - out_cap, 0)
    if debug_overflow:
        jax.debug.callback(_overflow_warn, comm.rank(), send_dropped,
                           recv_dropped, label=label)

    out = Table(out_cols, new_count).mask_padding()
    stats = ShuffleStats(sent_counts, recv_counts, send_dropped,
                         recv_dropped, shuffle_impl=impl,
                         a2a_chunks=a2a_chunks)
    return out, stats


def replicate_hot_rows(
    table: Table,
    comm: Communicator,
    is_hot: jax.Array,
    hot_cap: int,
    base: Table,
    pack: bool = True,
) -> Tuple[Table, ShuffleStats]:
    """Broadcast each rank's ``is_hot`` rows to every rank, appended to
    ``base`` (the skew-mitigated build side of a broadcast join).

    The salted join path excludes hot build rows from the hash shuffle
    (they route to the overflow bin ``p``, uncounted) and replicates them
    here instead: a stable compaction into ``(hot_cap,)`` slots, one
    packed ``all_gather``, then a prefix-sum append onto ``base`` past its
    ``row_count``.  Output capacity is the static
    ``base.capacity + p * hot_cap``; rows beyond ``hot_cap`` on one rank
    ARE counted as ``send_dropped`` (the decision layer sizes ``hot_cap``
    from an exact host count precisely so this stays zero).

    Must run inside a shard_map region over ``comm.axis``.
    """
    p = comm.size()
    cap = table.capacity
    k = min(int(hot_cap), cap)  # per-rank slots; static + rank-uniform
    hot = is_hot & table.valid_mask()
    n_hot = jnp.sum(hot.astype(jnp.int32))
    sent = jnp.minimum(n_hot, k)
    dropped = (n_hot - sent).astype(jnp.int32)

    order = jnp.argsort(jnp.where(hot, 0, 1), stable=True)[:k]
    counts = comm.all_gather(sent).reshape(p)           # (p,) everywhere
    offsets = jnp.cumsum(counts) - counts               # exclusive
    total = jnp.sum(counts)

    base_cap = base.capacity
    new_cap = base_cap + p * k
    start = base.row_count
    # start <= base_cap and total <= p*k, so the append never overflows
    idx = jnp.arange(p * k, dtype=jnp.int32)
    blk, q = idx // k, idx % k
    g_valid = q < jnp.take(counts, blk)
    pos = jnp.where(g_valid, start + jnp.take(offsets, blk) + q, new_cap)

    names = base.column_names
    dtypes = {n: table.columns[n].dtype for n in names}
    packables = [n for n in names
                 if dtypes[n] in (jnp.float32, jnp.int32, jnp.uint32,
                                  jnp.bool_)
                 and table.columns[n].ndim == 1] if pack else []
    singles = [n for n in names if n not in packables]

    def _append(n: str, flat: jax.Array) -> jax.Array:
        out = jnp.zeros((new_cap,) + flat.shape[1:], flat.dtype)
        out = out.at[:base_cap].set(base.columns[n])
        return out.at[pos].set(flat, mode="drop")

    out_cols: Dict[str, jax.Array] = {}
    if packables:
        packed = jnp.take(_pack_u32(table.columns, packables), order, axis=0)
        got = comm.all_gather(packed).reshape(p * k, len(packables))
        for n, v in _unpack_u32(got, packables, dtypes).items():
            out_cols[n] = _append(n, v)
    for n in singles:
        col = jnp.take(table.columns[n], order, axis=0)
        got = comm.all_gather(col).reshape((p * k,) + col.shape[1:])
        out_cols[n] = _append(n, got)

    new_count = (start + total).astype(jnp.int32)
    out = Table(out_cols, new_count).mask_padding()
    # this rank sends its ``sent`` hot rows to every rank and receives
    # each rank's contribution once — the honest wire accounting
    stats = ShuffleStats(jnp.full((p,), sent, jnp.int32), counts, dropped,
                         jnp.zeros((), jnp.int32))
    return out, stats

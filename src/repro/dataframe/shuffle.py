"""Distributed shuffle: capacity-based all-to-all (the paper's core comm op).

MPI AllToAllv sends exact per-destination byte counts; XLA collectives are
static-shape.  The adaptation (DESIGN.md §2) is the MoE-capacity idiom:

  1. hash keys -> destination rank (or take explicit destinations),
  2. counts exchange (tiny all_to_all) for observability + receive counts,
  3. rows are bucketed into a ``(p, bucket_capacity)`` send buffer
     (sort-by-destination + rank-within-bucket; overflow rows are dropped
     and *counted* — ``ShuffleStats.send_dropped``),
  4. ONE data all_to_all per packed buffer (4-byte columns are bitcast and
     packed into a single ``(p, cap, ncols)`` uint32 buffer so the shuffle
     issues a single large collective — the "fewer, larger messages"
     optimization the paper attributes to tuned MPI algorithms),
  5. receive-side compaction back to a fixed-capacity ``Table``.

The sample-based repartitioner (``sort.py`` splitters, paper §VI future
work) exists to keep bucket skew bounded so capacity factors stay small.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..comm import Communicator
from .ops_local import hash_columns
from .table import Table


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShuffleStats:
    """Per-rank observability for one shuffle (all traced arrays)."""

    sent_counts: jax.Array   # (p,) rows sent to each rank (post-capacity)
    recv_counts: jax.Array   # (p,) rows received from each rank
    send_dropped: jax.Array  # () rows dropped by send-bucket capacity
    recv_dropped: jax.Array  # () rows dropped by receive-table capacity

    def tree_flatten(self):
        return (self.sent_counts, self.recv_counts, self.send_dropped,
                self.recv_dropped), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def default_bucket_capacity(capacity: int, p: int, factor: float = 2.0) -> int:
    """Per-destination bucket size: balanced share × skew headroom, 8-aligned."""
    return max(8, _round_up(int(-(-capacity // p) * factor), 8))


def _pack_u32(cols: Dict[str, jax.Array], names) -> jax.Array:
    """Bitcast 4-byte columns to uint32 and stack: (cap,) xN -> (cap, N)."""
    parts = []
    for n in names:
        v = cols[n]
        if v.dtype == jnp.float32:
            v = jax.lax.bitcast_convert_type(v, jnp.uint32)
        elif v.dtype in (jnp.int32, jnp.uint32):
            v = v.view(jnp.uint32) if hasattr(v, "view") else jax.lax.bitcast_convert_type(v, jnp.uint32)
        else:
            raise TypeError(n)
        parts.append(v)
    return jnp.stack(parts, axis=-1)


def _unpack_u32(buf: jax.Array, names, dtypes) -> Dict[str, jax.Array]:
    out = {}
    for i, n in enumerate(names):
        v = buf[..., i]
        if dtypes[n] == jnp.float32:
            v = jax.lax.bitcast_convert_type(v, jnp.float32)
        else:
            v = v.astype(dtypes[n])
        out[n] = v
    return out


def shuffle(
    table: Table,
    comm: Communicator,
    key_cols: Optional[Sequence[str]] = None,
    dest: Optional[jax.Array] = None,
    bucket_capacity: Optional[int] = None,
    out_capacity: Optional[int] = None,
    capacity_factor: float = 2.0,
    pack: bool = True,
) -> Tuple[Table, ShuffleStats]:
    """Repartition rows across the comm axis by key hash or explicit dest.

    Must run inside a shard_map region over ``comm.axis``.
    """
    p = comm.size()
    cap = table.capacity
    bucket_cap = bucket_capacity or default_bucket_capacity(cap, p, capacity_factor)
    out_cap = out_capacity or cap
    valid = table.valid_mask()

    if dest is None:
        if not key_cols:
            raise ValueError("need key_cols or dest")
        h = hash_columns(table, key_cols)
        dest = (h % jnp.uint32(p)).astype(jnp.int32)
    dest = jnp.where(valid, dest, p)  # invalid rows -> overflow bin p

    # --- bucketize: stable sort rows by destination ---------------------- #
    order = jnp.argsort(dest, stable=True)
    sorted_dest = jnp.take(dest, order)
    pos = jnp.arange(cap, dtype=jnp.int32)
    bucket_start = jnp.searchsorted(sorted_dest, sorted_dest, side="left")
    rank_in_bucket = pos - bucket_start

    raw_counts = jax.ops.segment_sum(
        jnp.ones((cap,), jnp.int32), dest, num_segments=p + 1)[:p]
    sent_counts = jnp.minimum(raw_counts, bucket_cap)
    send_dropped = jnp.sum(raw_counts - sent_counts)

    in_bucket = (sorted_dest < p) & (rank_in_bucket < bucket_cap)
    slot = jnp.where(in_bucket, sorted_dest * bucket_cap + rank_in_bucket,
                     p * bucket_cap)  # out-of-range -> dropped by mode="drop"

    names = table.column_names
    dtypes = {n: table.columns[n].dtype for n in names}
    four_byte = [n for n in names
                 if dtypes[n] in (jnp.float32, jnp.int32, jnp.uint32)
                 and table.columns[n].ndim == 1]
    packables = four_byte if pack else []
    singles = [n for n in names if n not in packables]

    recv_cols: Dict[str, jax.Array] = {}

    def _scatter(col_sorted: jax.Array) -> jax.Array:
        buf = jnp.zeros((p * bucket_cap,) + col_sorted.shape[1:], col_sorted.dtype)
        return buf.at[slot].set(col_sorted, mode="drop")

    if packables:
        packed = _pack_u32(table.columns, packables)          # (cap, N)
        packed = jnp.take(packed, order, axis=0)
        buf = _scatter(packed).reshape(p, bucket_cap, len(packables))
        got = comm.all_to_all(buf).reshape(p * bucket_cap, len(packables))
        recv_cols.update(_unpack_u32(got, packables, dtypes))
    for n in singles:
        col = jnp.take(table.columns[n], order, axis=0)
        buf = _scatter(col).reshape((p, bucket_cap) + col.shape[1:])
        got = comm.all_to_all(buf)
        recv_cols[n] = got.reshape((p * bucket_cap,) + col.shape[1:])

    recv_counts = comm.exchange_counts(sent_counts)

    # --- receive-side compaction ----------------------------------------- #
    ridx = jnp.arange(p * bucket_cap, dtype=jnp.int32)
    r_valid = (ridx % bucket_cap) < jnp.take(recv_counts, ridx // bucket_cap)
    order2 = jnp.argsort(jnp.where(r_valid, 0, 1), stable=True)[:out_cap]
    total_recv = jnp.sum(recv_counts)
    new_count = jnp.minimum(total_recv, out_cap).astype(jnp.int32)
    out_cols = {n: jnp.take(v, order2, axis=0) for n, v in recv_cols.items()}

    out = Table(out_cols, new_count).mask_padding()
    stats = ShuffleStats(sent_counts, recv_counts, send_dropped,
                         jnp.maximum(total_recv - out_cap, 0))
    return out, stats

"""Bruck / recursive-doubling collective schedules (the UCC analogue).

Latency-optimal algorithms: ``all_to_all`` is the Bruck algorithm
(⌈log₂p⌉ steps, each moving half the buffer) [Bruck et al., IEEE TPDS'97,
the paper's ref 16]; ``all_gather``/``all_reduce`` use recursive doubling
when p is a power of two and fall back to ring otherwise — mirroring how
UCC/tuned-MPI select an algorithm per collective and message size.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .communicator import Communicator, register_communicator
from .ring import RingCommunicator, _shift_perm


@register_communicator
class BruckCommunicator(Communicator):
    name = "bruck"

    def __init__(self, axis: str):
        super().__init__(axis)
        self._ring = RingCommunicator(axis)

    # ------------------------------------------------------------------ #
    def all_to_all(self, x: jax.Array) -> jax.Array:
        p = self.size()
        r = self.rank()
        if p == 1:
            return x
        # Phase 1 — local rotation: slot i holds the block destined to rank
        # (r + i) % p ("relative destination i").
        idx = (r + jnp.arange(p)) % p
        b = jnp.take(x, idx, axis=0)
        # Phase 2 — log steps: slot-i blocks must travel distance i; move the
        # slots with bit k set by +2^k each step.
        nsteps = max(1, math.ceil(math.log2(p)))
        for k in range(nsteps):
            dist = 1 << k
            if dist >= p and p > 1 and (p & (p - 1)) == 0:
                break
            sel = [i for i in range(p) if (i >> k) & 1]
            if not sel:
                continue
            send = b[jnp.asarray(sel)]
            got = self.ppermute(send, _shift_perm(p, dist))
            b = b.at[jnp.asarray(sel)].set(got)
        # Phase 3 — slot i now holds the block from rank (r - i) % p destined
        # to us; reorder to rank-major.
        out_idx = (r - jnp.arange(p)) % p
        return jnp.take(b, out_idx, axis=0)

    # ------------------------------------------------------------------ #
    def all_gather(self, x: jax.Array) -> jax.Array:
        p = self.size()
        if p & (p - 1):  # not a power of two -> ring
            return self._ring.all_gather(x)
        r = self.rank()
        if p == 1:
            return x[None]
        buf = x[None]
        k = 0
        while (1 << k) < p:
            dist = 1 << k
            perm = [(s, s ^ dist) for s in range(p)]
            got = self.ppermute(buf, perm)
            buf = jnp.concatenate([buf, got], axis=0)  # buf[m] = rank (r ^ m)
            k += 1
        idx = r ^ jnp.arange(p)
        return jnp.take(buf, idx, axis=0)

    # ------------------------------------------------------------------ #
    def all_reduce(self, x: jax.Array) -> jax.Array:
        p = self.size()
        if p & (p - 1):
            return self._ring.all_reduce(x)
        v = x
        k = 0
        while (1 << k) < p:
            dist = 1 << k
            perm = [(s, s ^ dist) for s in range(p)]
            v = v + self.ppermute(v, perm)
            k += 1
        return v

    # ------------------------------------------------------------------ #
    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        # Small-payload regime: allreduce-then-slice (latency optimal).
        p = self.size()
        r = self.rank()
        if p == 1:
            return x[0]
        full = self.all_reduce(x)
        return jax.lax.dynamic_index_in_dim(full, r, axis=0, keepdims=False)

"""Modular communicator abstraction (the paper's §IV-B, adapted to JAX).

CylonFlow's second pillar is a *modularized communicator*: DDF communication
routines are written against an abstract interface, and concrete
high-performance backends (OpenMPI / Gloo / UCX+UCC in the paper) are plugged
in underneath.  On TPU the transport is fixed (ICI/XLA), but the *collective
schedule* is not — so the swappable dimension here is the algorithm:

  * ``xla``   — native ``jax.lax`` collectives (XLA's vendor-tuned schedules;
                the analogue of a tuned MPI implementation).
  * ``ring``  — (p-1)-step ring schedules built from ``ppermute``
                (bandwidth-optimal, latency O(p); the analogue of Gloo).
  * ``bruck`` — ⌈log₂p⌉-step Bruck all-to-all built from ``ppermute``
                (latency-optimal for small payloads; the analogue of UCC's
                algorithm selection).

All methods must be called *inside* a ``jax.shard_map`` region over ``axis``.

Block-major convention: ``all_to_all`` takes a local array of shape
``(p, m, ...)`` where block ``j`` is destined to rank ``j``; the output block
``j`` is the block received from rank ``j`` (MPI semantics).

NOTE ``ring``/``bruck`` unroll ``ppermute`` steps into the HLO; they are meant
for modest axis sizes (the paper benchmarks 1..512 processes; we benchmark
1..8 measured on CPU and 16 structurally).  The default for production meshes
is ``xla``.
"""

from __future__ import annotations

import abc
from typing import Any, Callable, Dict, Type

import jax
import jax.numpy as jnp

from .. import compat


class Communicator(abc.ABC):
    """Abstract DDF communicator bound to one mesh axis."""

    #: registry key, set by subclasses
    name: str = "abstract"

    def __init__(self, axis: str):
        self.axis = axis

    # ------------------------------------------------------------------ #
    # Introspection (valid inside shard_map only)
    # ------------------------------------------------------------------ #
    def size(self) -> int:
        return compat.axis_size(self.axis)

    def rank(self):
        return jax.lax.axis_index(self.axis)

    # ------------------------------------------------------------------ #
    # Collective routines (the set identified in the paper §III-B2)
    # ------------------------------------------------------------------ #
    @abc.abstractmethod
    def all_to_all(self, x: jax.Array) -> jax.Array:
        """x: (p, m, ...) block-major -> (p, m, ...); out[j] = block from rank j."""

    @abc.abstractmethod
    def all_gather(self, x: jax.Array) -> jax.Array:
        """x: (m, ...) -> (p, m, ...) stacked by rank."""

    @abc.abstractmethod
    def all_reduce(self, x: jax.Array) -> jax.Array:
        """Sum across the axis."""

    @abc.abstractmethod
    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        """x: (p, m, ...) block-major -> (m, ...): sum over ranks of block[rank]."""

    # Non-abstract conveniences -----------------------------------------#
    def all_to_all_chunked(self, x: jax.Array, chunks: int = 1) -> jax.Array:
        """All-to-all pipelined as ``chunks`` smaller collectives.

        ``x``: (p, m, ...) block-major; the capacity axis (axis 1) is split
        into ``chunks`` slices and one ``all_to_all`` is issued per slice
        (the AllToAllv chunking knob from tuned MPI: smaller in-flight
        messages, and independent collectives the scheduler may overlap
        with each other and with compute).  ``m`` is padded up to a
        multiple of ``chunks`` and the pad sliced back off.  Subclasses
        may override with a schedule-aware pipeline (see ``ring``).

        ``chunks`` must be a positive integer no larger than the capacity
        axis; invalid values raise ``ValueError`` up front (naming the
        axis and chunk count) instead of failing deep inside a reshape.
        """
        x, m, csz = self._chunk_split(x, chunks)
        if csz is None:
            return self.all_to_all(x)
        outs = [self.all_to_all(
            jax.lax.slice_in_dim(x, c * csz, (c + 1) * csz, axis=1))
            for c in range(chunks)]
        return jnp.concatenate(outs, axis=1)[:, :m]

    def _chunk_split(self, x: jax.Array, chunks: int):
        """Pad axis 1 to a multiple of ``chunks``; (x, orig_m, chunk_size).

        ``chunk_size`` is None when chunking degenerates to one collective.
        Validates ``chunks`` up front: a zero/negative/non-integer count or
        more chunks than capacity-axis rows would otherwise surface as an
        opaque division/reshape error deep inside the collective.
        """
        if x.ndim < 2:
            raise ValueError(
                f"all_to_all_chunked needs a (p, m, ...) block-major array "
                f"with a capacity axis to chunk; got shape {x.shape}")
        m = x.shape[1]
        if not isinstance(chunks, int) or isinstance(chunks, bool) \
                or chunks < 1:
            raise ValueError(
                f"all_to_all_chunked: chunks must be a positive int, got "
                f"{chunks!r} (capacity axis 1 has {m} rows)")
        if chunks > max(m, 1):
            raise ValueError(
                f"all_to_all_chunked: cannot split the capacity axis "
                f"(axis 1, {m} rows) into {chunks} chunks — chunks must "
                f"be <= rows; rows not divisible by chunks are padded")
        if chunks <= 1:
            return x, x.shape[1], None
        mp = -(-m // chunks) * chunks
        if mp != m:
            pad = jnp.zeros((x.shape[0], mp - m) + x.shape[2:], x.dtype)
            x = jnp.concatenate([x, pad], axis=1)
        return x, m, mp // chunks

    def broadcast(self, x: jax.Array, root: int = 0) -> jax.Array:
        """Broadcast rank ``root``'s value to every rank."""
        sel = jnp.where(self.rank() == root, 1, 0).astype(x.dtype)
        return self.all_reduce(x * sel)

    def all_reduce_max(self, x: jax.Array) -> jax.Array:
        return jax.lax.pmax(x, self.axis)

    def all_reduce_min(self, x: jax.Array) -> jax.Array:
        return jax.lax.pmin(x, self.axis)

    def exchange_counts(self, counts: jax.Array) -> jax.Array:
        """AllToAll of per-destination row counts (the AllToAllv counts round).

        counts: (p,) int32, counts[j] = rows this rank will send to rank j.
        Returns (p,) int32, recv[j] = rows rank j will send to this rank.
        """
        return self.all_to_all(counts.reshape(-1, 1))[:, 0]

    def ppermute(self, x: jax.Array, perm) -> jax.Array:
        return jax.lax.ppermute(x, self.axis, perm)


# ---------------------------------------------------------------------- #
# Registry
# ---------------------------------------------------------------------- #
_REGISTRY: Dict[str, Type[Communicator]] = {}


def register_communicator(cls: Type[Communicator]) -> Type[Communicator]:
    _REGISTRY[cls.name] = cls
    return cls


def get_communicator(name: str, axis: str) -> Communicator:
    """Instantiate a communicator by registry name, bound to ``axis``."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown communicator {name!r}; available: {sorted(_REGISTRY)}"
        ) from None
    return cls(axis)


def available_communicators():
    return sorted(_REGISTRY)

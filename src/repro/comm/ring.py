"""Ring collective schedules built from ``ppermute`` (the Gloo analogue).

Bandwidth-optimal, latency O(p).  Every step is a neighbour exchange on the
ring, so on a TPU torus each step maps onto a single ICI hop.  The (p-1)
steps are unrolled into the HLO, so this backend targets modest axis sizes
(the measured benchmarks use p ≤ 8 on CPU, p = 16 structurally); production
meshes default to ``xla``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .communicator import Communicator, register_communicator


def _shift_perm(p: int, k: int = 1):
    """Permutation sending rank s -> rank (s+k) % p (receive from s-k)."""
    return [(s, (s + k) % p) for s in range(p)]


def _dyn_block(x: jax.Array, i) -> jax.Array:
    """x[(i,)] with a traced index, keeping the block dims."""
    return jax.lax.dynamic_index_in_dim(x, i, axis=0, keepdims=False)


@register_communicator
class RingCommunicator(Communicator):
    name = "ring"

    # ------------------------------------------------------------------ #
    def all_gather(self, x: jax.Array) -> jax.Array:
        p = self.size()
        r = self.rank()
        if p == 1:
            return x[None]
        # rel[k] = block originating at rank (r - k) % p
        rel = [x]
        cur = x
        perm = _shift_perm(p, 1)
        for _ in range(1, p):
            cur = self.ppermute(cur, perm)
            rel.append(cur)
        stacked = jnp.stack(rel)
        # out[j] = block from rank j = rel[(r - j) % p]
        idx = (r - jnp.arange(p)) % p
        return jnp.take(stacked, idx, axis=0)

    # ------------------------------------------------------------------ #
    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        # x: (p, m, ...) block-major; rank r ends with sum_i x_i[r].
        p = self.size()
        r = self.rank()
        if p == 1:
            return x[0]
        perm = _shift_perm(p, 1)
        # Token for chunk j starts at rank (j+1)%p and travels the full ring,
        # accumulating each host's contribution for chunk j on the way.
        v = _dyn_block(x, (r - 1) % p)
        for t in range(1, p):
            v = self.ppermute(v, perm)
            v = v + _dyn_block(x, (r - 1 - t) % p)
        return v  # token now carries chunk r, fully reduced

    # ------------------------------------------------------------------ #
    def all_reduce(self, x: jax.Array) -> jax.Array:
        p = self.size()
        if p == 1:
            return x
        shape, dtype = x.shape, x.dtype
        flat = x.reshape(-1)
        n = flat.shape[0]
        chunk = -(-n // p)  # ceil
        pad = chunk * p - n
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), dtype)])
        blocks = flat.reshape(p, chunk)
        mine = self.reduce_scatter(blocks)          # (chunk,)
        full = self.all_gather(mine).reshape(-1)     # (p*chunk,)
        return full[:n].reshape(shape)

    # ------------------------------------------------------------------ #
    def all_to_all(self, x: jax.Array) -> jax.Array:
        # Pairwise-exchange schedule: at step k every rank sends its block
        # (r+k)%p directly to rank (r+k)%p; p-1 steps.
        p = self.size()
        r = self.rank()
        if p == 1:
            return x
        rel = [_dyn_block(x, r)]  # rel[k] = block received from rank (r-k)%p
        for k in range(1, p):
            send = _dyn_block(x, (r + k) % p)
            rel.append(self.ppermute(send, _shift_perm(p, k)))
        stacked = jnp.stack(rel)
        idx = (r - jnp.arange(p)) % p  # out[j] = rel[(r-j)%p]
        return jnp.take(stacked, idx, axis=0)

    # ------------------------------------------------------------------ #
    def all_to_all_chunked(self, x: jax.Array, chunks: int = 1) -> jax.Array:
        # Step-major double-buffered pipeline: instead of running chunk c's
        # full (p-1)-step exchange before starting chunk c+1 (the base-class
        # chunk-major loop), issue step k for EVERY chunk back-to-back.
        # Consecutive ppermutes then carry independent buffers, so while one
        # chunk's exchange is on the wire the next chunk's send buffer is
        # being sliced/packed — the classic comm/compute double-buffer.
        p = self.size()
        r = self.rank()
        x, m, csz = self._chunk_split(x, chunks)
        if csz is None or p == 1:
            return self.all_to_all(x[:, :m])
        xs = [jax.lax.slice_in_dim(x, c * csz, (c + 1) * csz, axis=1)
              for c in range(chunks)]
        # rel[c][k] = chunk-c block received from rank (r-k)%p
        rel = [[_dyn_block(xc, r)] for xc in xs]
        for k in range(1, p):
            perm = _shift_perm(p, k)
            for c in range(chunks):
                send = _dyn_block(xs[c], (r + k) % p)
                rel[c].append(self.ppermute(send, perm))
        idx = (r - jnp.arange(p)) % p
        outs = [jnp.take(jnp.stack(rc), idx, axis=0) for rc in rel]
        return jnp.concatenate(outs, axis=1)[:, :m]

"""Native XLA collective schedules (the tuned-MPI analogue).

These lower to single HLO collective ops (all-to-all / all-gather /
all-reduce / reduce-scatter), letting XLA pick the ICI schedule.  This is the
production default on real pods.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .communicator import Communicator, register_communicator


@register_communicator
class XlaCommunicator(Communicator):
    name = "xla"

    def all_to_all(self, x: jax.Array) -> jax.Array:
        # x: (p, m, ...) block-major.  tiled=False splits axis0 across ranks
        # and stacks the received blocks along a fresh axis0, which is exactly
        # the MPI convention probed in tests.
        return jax.lax.all_to_all(x, self.axis, split_axis=0, concat_axis=0, tiled=False)

    def all_gather(self, x: jax.Array) -> jax.Array:
        return jax.lax.all_gather(x, self.axis, tiled=False)

    def all_reduce(self, x: jax.Array) -> jax.Array:
        return jax.lax.psum(x, self.axis)

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        # psum_scatter with tiled=False consumes the leading (p,) block axis.
        return jax.lax.psum_scatter(x, self.axis, scatter_dimension=0, tiled=False)

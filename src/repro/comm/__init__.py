"""Modular communicator layer (paper §IV-B): swappable collective schedules."""

from .communicator import (
    Communicator,
    available_communicators,
    get_communicator,
    register_communicator,
)
from .xla import XlaCommunicator
from .ring import RingCommunicator
from .bruck import BruckCommunicator

__all__ = [
    "Communicator",
    "XlaCommunicator",
    "RingCommunicator",
    "BruckCommunicator",
    "available_communicators",
    "get_communicator",
    "register_communicator",
]

"""Model zoo: composable blocks + the generic decoder stack.

``config``     — ModelConfig / MoEConfig / MLAConfig / SSMConfig + SHAPES
``layers``     — RMSNorm, RoPE, gated MLP, sharding rules
``attention``  — GQA (+qk-norm) and MLA, full-seq + decode
``mamba2``     — SSD mixer, full-seq + decode
``moe``        — sort-based grouped capacity dispatch (+ einsum oracle)
``transformer``— stack assembly, loss, prefill/decode, param/cache specs
"""

from .config import MLAConfig, ModelConfig, MoEConfig, SHAPES, SSMConfig
from .layers import NO_SHARDING, ShardingRules
from . import transformer

__all__ = [
    "MLAConfig", "ModelConfig", "MoEConfig", "SHAPES", "SSMConfig",
    "NO_SHARDING", "ShardingRules", "transformer",
]

"""Model configuration dataclasses for the assigned architecture pool."""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0            # shared (always-on) experts
    every_k_layers: int = 1        # MoE every k-th layer (jamba: 2)
    first_dense_d_ff: Optional[int] = None  # deepseek: layer 0 is dense
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    #: collective schedule for the dispatch shuffle (paper §IV-B modular
    #: communicator): "xla" | "ring" | "bruck"
    communicator: str = "xla"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    d_conv: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | ssm | moe | vlm | hybrid | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads (gemma: 256)
    qk_norm: bool = False           # qwen3
    act: str = "silu"               # silu (SwiGLU) | gelu (GeGLU, gemma)
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    #: per-layer kind pattern, cycled over layers: "a"=attention, "m"=mamba
    layer_pattern: str = "a"
    num_codebooks: int = 1          # musicgen: EnCodec codebooks
    embed_inputs: bool = False      # vlm: consumes precomputed embeddings
    #: True if any layer is attention-free or sub-quadratic (long_500k eligible)
    sub_quadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows: vocab rounded up to a multiple of 256 so
        the vocab axis divides any production model-axis size (GPT-NeoX
        style padding; padded logits are masked to -inf in the loss)."""
        return -(-self.vocab_size // 256) * 256

    def layer_kind(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        if self.moe.first_dense_d_ff is not None and i == 0:
            return False
        return (i % self.moe.every_k_layers) == (self.moe.every_k_layers - 1) \
            if self.moe.every_k_layers > 1 else True

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d * (1 if self.tie_embeddings else 2) * (
            self.num_codebooks if self.family == "audio" else 1)
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind == "a":
                if self.mla is not None:
                    m = self.mla
                    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
                    total += d * self.num_heads * qd                      # q
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)    # down
                    total += m.kv_lora_rank * self.num_heads * (
                        m.qk_nope_head_dim + m.v_head_dim)                # up
                    total += self.num_heads * m.v_head_dim * d            # o
                else:
                    total += d * self.num_heads * hd * 2                  # q, o
                    total += d * self.num_kv_heads * hd * 2               # k, v
            else:  # mamba
                s = self.ssm
                d_in = s.expand * d
                total += d * (2 * d_in + 2 * s.d_state + d_in // s.head_dim)
                total += d_in * s.d_conv + d_in * d
            if self.is_moe_layer(i):
                m = self.moe
                total += (m.num_experts + m.num_shared) * 3 * d * m.d_ff_expert
                total += d * m.num_experts                                 # router
            elif kind == "a" or self.family in ("ssm",):
                if kind == "a":
                    ff = (self.moe.first_dense_d_ff
                          if (self.moe and self.moe.first_dense_d_ff and i == 0)
                          else self.d_ff)
                    if ff:
                        total += 3 * d * ff
            total += 2 * d                                                 # norms
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        n_moe_layers = sum(1 for i in range(self.num_layers)
                           if self.is_moe_layer(i))
        inactive = (m.num_experts - m.top_k) * 3 * self.d_model \
            * m.d_ff_expert * n_moe_layers
        return full - inactive


# The four assigned input-shape cells (per-arch eligibility in launch/shapes).
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

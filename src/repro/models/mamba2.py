"""Mamba-2 (SSD) block: in_proj -> causal conv -> SSD scan -> gated norm -> out.

Train/prefill uses the chunked SSD (Pallas kernel on TPU, chunked-jnp on
CPU/dry-run — both validated against the naive recurrence oracle).  Decode
carries a constant-size state (heads × N × P) + a (d_conv-1) conv tail, which
is why the SSM archs are the ones eligible for the 500k-context cell.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..kernels.ssd_scan import ssd_scan, ssd_scan_chunked_jnp
from .config import ModelConfig
from .layers import (NO_SHARDING, Params, ShardingRules, constrain,
                     dense_init, rmsnorm, rmsnorm_init)


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    return s, d_in, n_heads


def mamba_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    s, d_in, nh = _dims(cfg)
    ks = jax.random.split(key, 4)
    # in_proj emits [z (d_in), x (d_in), B (N), C (N), dt (nh)]
    proj_out = 2 * d_in + 2 * s.d_state + nh
    return {
        "w_in": dense_init(ks[0], (cfg.d_model, proj_out), 0, dtype),
        "conv": (jax.random.normal(ks[1], (s.d_conv, d_in), jnp.float32)
                 * 0.1).astype(dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),        # A = -exp(a_log) in (-1,0]
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),  # softplus -> small dt
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm": rmsnorm_init(d_in, dtype),
        "w_out": dense_init(ks[3], (d_in, cfg.d_model), 0, dtype),
    }


def mamba_specs(cfg: ModelConfig, rules: ShardingRules) -> Params:
    return {
        "w_in": rules.logical("fsdp", "tp"),
        "conv": rules.logical(None, "tp"),
        "a_log": rules.logical(None),
        "dt_bias": rules.logical(None),
        "d_skip": rules.logical(None),
        "norm": {"scale": rules.logical(None)},
        "w_out": rules.logical("tp", "fsdp"),
    }


def _split_proj(proj, cfg):
    s, d_in, nh = _dims(cfg)
    z = proj[..., :d_in]
    x = proj[..., d_in:2 * d_in]
    bmat = proj[..., 2 * d_in:2 * d_in + s.d_state]
    cmat = proj[..., 2 * d_in + s.d_state:2 * d_in + 2 * s.d_state]
    dt = proj[..., 2 * d_in + 2 * s.d_state:]
    return z, x, bmat, cmat, dt


def mamba_forward(params: Params, u: jax.Array, cfg: ModelConfig,
                  rules: ShardingRules = NO_SHARDING,
                  impl: str = "auto", return_state: bool = False):
    """Full-sequence SSD. u: (B, S, D) -> (B, S, D).

    With ``return_state`` also returns (ssm_state (B, nh, N, P),
    conv_state (B, d_conv-1, d_in)) — the prefill→decode hand-off.
    """
    s_cfg, d_in, nh = _dims(cfg)
    b, t, _ = u.shape
    proj = u @ params["w_in"]
    z, x_raw, bmat, cmat, dt = _split_proj(proj, cfg)

    # causal depthwise conv over time (kernel d_conv)
    pad = jnp.pad(x_raw, ((0, 0), (s_cfg.d_conv - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + t] * params["conv"][i][None, None]
               for i in range(s_cfg.d_conv))
    x = jax.nn.silu(conv.astype(jnp.float32)).astype(u.dtype)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,nh)
    a = -jnp.exp(params["a_log"])                                     # (nh,)

    # reshape to (B*nh, T, P): heads scan independently
    xh = x.reshape(b, t, nh, s_cfg.head_dim).transpose(0, 2, 1, 3) \
          .reshape(b * nh, t, s_cfg.head_dim)
    dth = dt.transpose(0, 2, 1).reshape(b * nh, t, 1)
    ah = jnp.tile(a[None, :], (b, 1)).reshape(b * nh, 1)
    bh = jnp.repeat(bmat.astype(jnp.float32), nh, axis=0).reshape(
        b, nh, t, s_cfg.d_state)[:, :].reshape(b * nh, t, s_cfg.d_state) \
        if False else jnp.broadcast_to(
            bmat[:, None].astype(jnp.float32),
            (b, nh, t, s_cfg.d_state)).reshape(b * nh, t, s_cfg.d_state)
    ch = jnp.broadcast_to(cmat[:, None].astype(jnp.float32),
                          (b, nh, t, s_cfg.d_state)
                          ).reshape(b * nh, t, s_cfg.d_state)

    if impl == "auto":
        impl = "kernel" if jax.default_backend() == "tpu" else "chunked"
    if impl == "kernel":
        y, h_fin = ssd_scan(xh.astype(jnp.float32), dth, ah, bh, ch,
                            chunk=s_cfg.chunk)
    else:
        y, h_fin = ssd_scan_chunked_jnp(xh.astype(jnp.float32), dth, ah, bh,
                                        ch, chunk=s_cfg.chunk)
    # D skip (per head)
    y = y.reshape(b, nh, t, s_cfg.head_dim) \
        + params["d_skip"][None, :, None, None] * xh.reshape(
            b, nh, t, s_cfg.head_dim)
    y = y.transpose(0, 2, 1, 3).reshape(b, t, d_in).astype(u.dtype)

    y = rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)
                                                ).astype(u.dtype), cfg.norm_eps)
    if rules.tp_weights:  # TP hidden (serving) vs SP hidden (training)
        y = constrain(y, rules, "batch", None, "model")
    else:
        y = constrain(y, rules, "batch", "model", None)
    out = y @ params["w_out"]
    if not return_state:
        return out
    ssm_state = h_fin.reshape(b, nh, s_cfg.d_state, s_cfg.head_dim)
    conv_state = pad[:, t:t + s_cfg.d_conv - 1]   # last d_conv-1 raw inputs
    return out, ssm_state, conv_state


def mamba_decode(params: Params, u: jax.Array, ssm_state: jax.Array,
                 conv_state: jax.Array, cfg: ModelConfig,
                 rules: ShardingRules = NO_SHARDING
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token step. u: (B, 1, D); ssm_state: (B, nh, N, P);
    conv_state: (B, d_conv-1, d_in)."""
    s_cfg, d_in, nh = _dims(cfg)
    b = u.shape[0]
    proj = u[:, 0] @ params["w_in"]
    z, x, bmat, cmat, dt = _split_proj(proj, cfg)

    # conv with cached tail
    window = jnp.concatenate([conv_state, x[:, None]], axis=1)  # (B, d_conv, d_in)
    conv = jnp.einsum("bkd,kd->bd", window.astype(jnp.float32),
                      params["conv"].astype(jnp.float32))
    x = jax.nn.silu(conv).astype(u.dtype)
    conv_state = window[:, 1:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B, nh)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(a[None] * dt)                                     # (B, nh)

    xh = x.reshape(b, nh, s_cfg.head_dim).astype(jnp.float32)
    inject = dt[..., None, None] * jnp.einsum(
        "bn,bhp->bhnp", bmat.astype(jnp.float32), xh)
    ssm_state = decay[..., None, None] * ssm_state + inject
    y = jnp.einsum("bn,bhnp->bhp", cmat.astype(jnp.float32), ssm_state)
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(b, d_in).astype(u.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)
                                                ).astype(u.dtype), cfg.norm_eps)
    return (y @ params["w_out"])[:, None], ssm_state, conv_state

"""Attention blocks: GQA (+qk-norm), MLA, decode paths, impl selection.

Three interchangeable implementations for full-sequence attention:

  * ``dense``   — quadratic reference (small seqs / tests)
  * ``chunked`` — online-softmax lax.scan over KV blocks: differentiable,
                  O(S·block) memory, compiles on any backend (the dry-run
                  path; XLA CPU cannot lower Mosaic kernels)
  * ``flash``   — the Pallas kernel (TPU runtime)

``auto`` picks dense below 2k keys, else chunked on CPU / flash on TPU.
Decode (single query against a cache) is pure jnp; with the KV sequence axis
sharded over the ``model`` mesh axis, GSPMD turns the softmax reductions into
the flash-decoding-style distributed combine (psum of partial max/sum).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.flash_attention import attention_ref, flash_attention
from .. import flags
from .config import ModelConfig
from .layers import (NO_SHARDING, Params, ShardingRules, apply_rope, constrain,
                     dense_init, rmsnorm, rmsnorm_init)


# ---------------------------------------------------------------------- #
# Chunked (online softmax) attention — differentiable, any backend
# ---------------------------------------------------------------------- #
def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True, scale: Optional[float] = None,
                      block_k: int = 512,
                      rules: ShardingRules = NO_SHARDING) -> jax.Array:
    """q: (B, Hq, Sq, D); k: (B, Hkv, Sk, D); v: (B, Hkv, Sk, Dv).

    Dv may differ from D (MLA value heads are narrower than qk heads).
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    dv = v.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    group = hq // hkv
    bk = min(block_k, sk)
    if sk % bk:
        pad = bk - sk % bk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        sk_p = sk + pad
    else:
        sk_p = sk
    nkb = sk_p // bk
    kb = jnp.moveaxis(k.reshape(b, hkv, nkb, bk, d), 2, 0)
    vb = jnp.moveaxis(v.reshape(b, hkv, nkb, bk, dv), 2, 0)

    # grouped-query layout: (B, Hkv, G, Sq, D) — K/V are never head-repeated
    # (a materialized repeat triples the K/V cotangent collectives under SP)
    qf = q.reshape(b, hkv, group, sq, d).astype(jnp.float32)
    qpos = jnp.arange(sq)

    def step(carry, inp):
        m, l, acc = carry
        ki, kc, vc = inp
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kc) * scale
        kpos = ki * bk + jnp.arange(bk)
        if causal:
            mask = qpos[:, None] >= kpos[None, :]
        else:
            mask = jnp.ones((sq, bk), bool)
        mask = mask & (kpos < sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, vc)
        return (m_new, l_new, acc_new), None

    # the scan carry must start sequence-sharded: a zeros-init carry has no
    # sharding for GSPMD to propagate, and a replicated (B, H, Sq, D) f32
    # running state costs ~40 GB/device at jamba scale (EXPERIMENTS §Perf)
    def _c(x):
        return constrain(x, rules, "batch", None, None, "model", None)
    init = (_c(jnp.full((b, hkv, group, sq, 1), -1e30, jnp.float32)),
            _c(jnp.zeros((b, hkv, group, sq, 1), jnp.float32)),
            _c(jnp.zeros((b, hkv, group, sq, dv), jnp.float32)))
    (m, l, acc), _ = jax.lax.scan(step, init, (jnp.arange(nkb), kb, vb),
                                  unroll=flags.scan_unroll_inner())
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


def attention_impl(q, k, v, causal: bool = True, scale=None,
                   impl: str = "auto",
                   rules: ShardingRules = NO_SHARDING) -> jax.Array:
    if impl == "auto":
        if k.shape[2] <= 2048:
            impl = "dense"
        else:
            impl = "flash" if jax.default_backend() == "tpu" else "chunked"
    if impl == "dense":
        return attention_ref(q, k, v, causal=causal, scale=scale)
    if impl == "chunked":
        return chunked_attention(q, k, v, causal=causal, scale=scale,
                                 rules=rules)
    if impl == "flash":
        return flash_attention(q, k, v, causal=causal, scale=scale)
    raise ValueError(impl)


# ---------------------------------------------------------------------- #
# GQA block
# ---------------------------------------------------------------------- #
def gqa_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d, hq, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq * hd), 0, dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), 0, dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), 0, dtype),
        "wo": dense_init(ks[3], (hq * hd, d), 0, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def gqa_specs(cfg: ModelConfig, rules: ShardingRules) -> Params:
    s = {
        "wq": rules.logical("fsdp", "tp"),
        "wk": rules.logical("fsdp", "tp"),
        "wv": rules.logical("fsdp", "tp"),
        "wo": rules.logical("tp", "fsdp"),
    }
    if cfg.qk_norm:
        s["q_norm"] = {"scale": rules.logical(None)}
        s["k_norm"] = {"scale": rules.logical(None)}
    return s


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd).transpose(0, 2, 1, 3)  # (B, H, S, hd)


def gqa_attention(params: Params, x: jax.Array, cfg: ModelConfig,
                  positions: jax.Array, rules: ShardingRules = NO_SHARDING,
                  impl: str = "auto") -> jax.Array:
    """Full-sequence causal attention. x: (B, S, D)."""
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = _split_heads(x @ params["wq"], hq, hd)
    k = _split_heads(x @ params["wk"], hkv, hd)
    v = _split_heads(x @ params["wv"], hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions[:, None, :], cfg.rope_theta)
    k = apply_rope(k, positions[:, None, :], cfg.rope_theta)
    # Sequence-parallel attention: q stays sequence-sharded over 'model'
    # (propagated from the SP block input); K/V are explicitly gathered
    # over 'model' — constraining the small bf16 K/V here stops GSPMD from
    # gathering the 4x-larger f32 block input instead.  No head-dim
    # constraints — head counts (24, 8, 56...) rarely divide the model
    # axis and padded head sharding forces catastrophic remat collectives.
    q = constrain(q, rules, "batch", None, "model", None)
    k = constrain(k, rules, "batch", None, None, None)
    v = constrain(v, rules, "batch", None, None, None)
    o = attention_impl(q, k, v, causal=True, impl=impl, rules=rules)
    o = o.transpose(0, 2, 1, 3).reshape(x.shape[0], x.shape[1], hq * hd)
    return o @ params["wo"]


def gqa_decode(params: Params, x: jax.Array, k_cache: jax.Array,
               v_cache: jax.Array, pos: jax.Array, cfg: ModelConfig,
               rules: ShardingRules = NO_SHARDING
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: (B, 1, D); caches: (B, Hkv, S, hd); pos: (B,)."""
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    b = x.shape[0]
    q = _split_heads(x @ params["wq"], hq, hd)           # (B, Hq, 1, hd)
    k_new = _split_heads(x @ params["wk"], hkv, hd)      # (B, Hkv, 1, hd)
    v_new = _split_heads(x @ params["wv"], hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k_new = rmsnorm(params["k_norm"], k_new, cfg.norm_eps)
    q = apply_rope(q, pos[:, None, None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos[:, None, None], cfg.rope_theta)

    # cache write at pos (same pos for all batch rows in this framework)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), pos[0], axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), pos[0], axis=2)

    s_max = k_cache.shape[2]
    group = hq // hkv
    # grouped-query einsum — no materialized K/V head repeat
    qg = q.reshape(b, hkv, group, hd).astype(jnp.float32)      # (B,Hkv,G,hd)
    kk = k_cache.astype(jnp.float32)
    vv = v_cache.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bhkd->bhgk", qg, kk) / math.sqrt(hd)
    mask = jnp.arange(s_max)[None, None, None, :] <= pos[0]
    scores = jnp.where(mask, scores, -1e30)
    # softmax over the (possibly model-sharded) cache axis: GSPMD inserts the
    # distributed max/sum combine (flash-decoding style)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, vv).astype(x.dtype)
    o = o.reshape(b, 1, hq * hd)
    return o @ params["wo"], k_cache, v_cache


# ---------------------------------------------------------------------- #
# MLA block (DeepSeek-V2): compressed-latent KV
# ---------------------------------------------------------------------- #
def mla_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    m = cfg.mla
    d, hq = cfg.d_model, cfg.num_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], (d, hq * qd), 0, dtype),
        "w_dkv": dense_init(ks[1], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                            0, dtype),
        "w_uk": dense_init(ks[2], (m.kv_lora_rank, hq * m.qk_nope_head_dim),
                           0, dtype),
        "w_uv": dense_init(ks[3], (m.kv_lora_rank, hq * m.v_head_dim),
                           0, dtype),
        "wo": dense_init(ks[4], (hq * m.v_head_dim, d), 0, dtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
    }


def mla_specs(cfg: ModelConfig, rules: ShardingRules) -> Params:
    return {
        "wq": rules.logical("fsdp", "tp"),
        "w_dkv": rules.logical("fsdp", None),
        "w_uk": rules.logical(None, "tp"),
        "w_uv": rules.logical(None, "tp"),
        "wo": rules.logical("tp", "fsdp"),
        "kv_norm": {"scale": rules.logical(None)},
    }


def mla_attention(params: Params, x: jax.Array, cfg: ModelConfig,
                  positions: jax.Array, rules: ShardingRules = NO_SHARDING,
                  impl: str = "auto") -> jax.Array:
    """Full-sequence MLA. x: (B, S, D)."""
    m = cfg.mla
    b, s, d = x.shape
    hq = cfg.num_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = (x @ params["wq"]).reshape(b, s, hq, nope + rope_d).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions[:, None, :], cfg.rope_theta)

    ckv = x @ params["w_dkv"]                               # (B, S, lora+rope)
    c_kv, k_rope = ckv[..., :m.kv_lora_rank], ckv[..., m.kv_lora_rank:]
    c_kv = rmsnorm(params["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, None], positions[:, None, :],
                        cfg.rope_theta)                     # (B, 1, S, rope_d)

    k_nope = (c_kv @ params["w_uk"]).reshape(b, s, hq, nope).transpose(0, 2, 1, 3)
    v = (c_kv @ params["w_uv"]).reshape(b, s, hq, vd).transpose(0, 2, 1, 3)

    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    kk = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (b, hq, s, rope_d))], axis=-1)
    qq = constrain(qq, rules, "batch", None, "model", None)  # SP queries
    kk = constrain(kk, rules, "batch", None, None, None)     # gathered K/V
    v = constrain(v, rules, "batch", None, None, None)
    scale = 1.0 / math.sqrt(nope + rope_d)
    # v head dim != qk head dim -> dense/chunked path (kernel assumes equal D)
    o = attention_impl(qq, kk, v, causal=True, scale=scale,
                       impl="chunked" if impl in ("auto", "flash") and s > 2048
                       else ("dense" if impl in ("auto", "flash") else impl),
                       rules=rules)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * vd)
    return o @ params["wo"]


def mla_decode(params: Params, x: jax.Array, ckv_cache: jax.Array,
               pos: jax.Array, cfg: ModelConfig,
               rules: ShardingRules = NO_SHARDING
               ) -> Tuple[jax.Array, jax.Array]:
    """Absorbed-MLA decode: score in the latent space, cache only c_kv+rope.

    x: (B, 1, D); ckv_cache: (B, S, lora+rope).  This is MLA's point: the
    cache is rank-compressed (576 floats/token vs Hkv·hd·2).
    """
    m = cfg.mla
    b = x.shape[0]
    hq = cfg.num_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    lora = m.kv_lora_rank

    q = (x @ params["wq"]).reshape(b, hq, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, pos[:, None], cfg.rope_theta)

    ckv_new = x[:, 0] @ params["w_dkv"]                    # (B, lora+rope)
    c_new = rmsnorm(params["kv_norm"], ckv_new[..., :lora], cfg.norm_eps)
    r_new = apply_rope(ckv_new[..., None, lora:], pos[:, None],
                       cfg.rope_theta)[..., 0, :]
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(
        ckv_cache, jnp.concatenate([c_new, r_new], -1)[:, None].astype(
            ckv_cache.dtype), pos[0], axis=1)

    c_all = ckv_cache[..., :lora].astype(jnp.float32)      # (B, S, lora)
    r_all = ckv_cache[..., lora:].astype(jnp.float32)      # (B, S, rope_d)

    # absorb W_uk into q: (B, Hq, nope) @ (lora, Hq*nope) -> (B, Hq, lora)
    w_uk = params["w_uk"].reshape(lora, hq, nope).astype(jnp.float32)
    q_lat = jnp.einsum("bhn,lhn->bhl", q_nope.astype(jnp.float32), w_uk)
    scores = jnp.einsum("bhl,bsl->bhs", q_lat, c_all)
    scores += jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32), r_all)
    scores = scores / math.sqrt(nope + rope_d)
    mask = jnp.arange(ckv_cache.shape[1])[None, None, :] <= pos[0]
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsl->bhl", p, c_all)             # (B, Hq, lora)
    w_uv = params["w_uv"].reshape(lora, hq, vd).astype(jnp.float32)
    o = jnp.einsum("bhl,lhv->bhv", ctx, w_uv).astype(x.dtype)
    return o.reshape(b, 1, hq * vd) @ params["wo"], ckv_cache

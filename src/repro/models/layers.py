"""Shared neural-net building blocks (pure-pytree params, GSPMD-sharded).

No flax/optax in this environment; parameters are nested dicts of arrays and
every block is ``apply(params, x, ...)``.  Sharding is expressed through
``ShardingRules`` which maps logical axes -> mesh axes; ``spec_for`` builds
the PartitionSpec tree for a param tree (used by train/serve/launch), and
``constrain`` applies activation sharding constraints inside jit (no-op when
no mesh axes are configured, e.g. in single-device tests).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Dict[str, Any]


# ---------------------------------------------------------------------- #
# Sharding rules: logical axes -> mesh axes
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical->physical axis mapping.

    Two weight schemes share one spec vocabulary (see DESIGN.md §5):

    * **train** (``tp_weights=False``): ZeRO-3 — weights sharded over
      ``fsdp`` only and *replicated over model*; the model axis carries
      sequence-parallel activations, expert parallelism, and the vocab-
      parallel embedding.  Weight ``tp`` dims resolve to ``None``.
    * **serve** (``tp_weights=True``): Megatron TP — weight ``tp`` dims
      resolve to the model axis so decode reads only the local shard and
      psums tiny (B, 1, D) activations instead of gathering weights
      per token.

    ``model`` in a spec always means the physical model axis (experts,
    vocab, sequence/KV sharding); ``tp`` means "model axis iff serving".
    """

    batch: Union[str, Tuple[str, ...], None] = None   # ('pod','data')
    fsdp: Union[str, None] = None                     # 'data'
    model: Union[str, None] = None                    # 'model'
    tp_weights: bool = False
    model_size: int = 1                               # physical axis sizes
    data_size: int = 1

    def logical(self, *axes: Optional[str]) -> P:
        """Build a PartitionSpec from logical axis names."""
        out = []
        for a in axes:
            if a is None:
                out.append(None)
            elif a == "batch":
                out.append(self.batch)
            elif a == "fsdp":
                out.append(self.fsdp)
            elif a == "model":
                out.append(self.model)
            elif a == "tp":
                out.append(self.model if self.tp_weights else None)
            else:
                raise ValueError(a)
        return P(*out)

    @property
    def enabled(self) -> bool:
        return any(x is not None for x in (self.batch, self.fsdp, self.model))


NO_SHARDING = ShardingRules()


def constrain(x: jax.Array, rules: ShardingRules, *axes: Optional[str]):
    """Activation sharding constraint (identity when rules disabled)."""
    if not rules.enabled:
        return x
    return jax.lax.with_sharding_constraint(x, rules.logical(*axes))


# ---------------------------------------------------------------------- #
# Initializers
# ---------------------------------------------------------------------- #
def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------- #
# RMSNorm
# ---------------------------------------------------------------------- #
def rmsnorm_init(d: int, dtype=jnp.bfloat16) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------- #
# Rotary position embedding
# ---------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- #
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------- #
def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), 0, dtype),
        "w_up": dense_init(k2, (d_model, d_ff), 0, dtype),
        "w_down": dense_init(k3, (d_ff, d_model), 0, dtype),
    }


def mlp_specs(rules: ShardingRules) -> Params:
    return {
        "w_gate": rules.logical("fsdp", "tp"),
        "w_up": rules.logical("fsdp", "tp"),
        "w_down": rules.logical("tp", "fsdp"),
    }


def mlp(params: Params, x: jax.Array, act: str = "silu",
        rules: ShardingRules = NO_SHARDING) -> jax.Array:
    gate = x @ params["w_gate"]
    up = x @ params["w_up"]
    if act == "silu":
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    elif act == "gelu":
        h = jax.nn.gelu(gate.astype(jnp.float32), approximate=True
                        ).astype(x.dtype) * up
    else:
        raise ValueError(act)
    # Scheme-aware hidden sharding: under ZeRO+SP (training) the hidden is
    # sequence-sharded — an ff-over-'model' constraint would force a partial
    # down-proj + full-activation all-reduce per layer.  Under TP (serving,
    # S=1) it is the opposite: the hidden MUST stay ff-sharded or GSPMD
    # all-gathers the full weight matrices per decoded token.
    if rules.tp_weights:
        h = constrain(h, rules, "batch", None, "model")
    else:
        h = constrain(h, rules, "batch", "model", None)
    return h @ params["w_down"]


# ---------------------------------------------------------------------- #
# Cross-entropy (fp32 logits, optional z-loss)
# ---------------------------------------------------------------------- #
def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          z_loss: float = 1e-4) -> jax.Array:
    """logits (..., V) any float dtype; labels (...) int32. Mean over all."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse ** 2
    return jnp.mean(loss)

"""Decoder-stack assembly for every assigned architecture.

One generic stack covers the whole pool via the config's ``layer_pattern``
(attention / mamba interleave), ``moe`` placement, and family-specific
frontends (text embeddings, VLM patch embeddings, audio codebooks).

Structure
---------
* **prefix layers** — layers that break the periodic pattern (deepseek's
  dense layer 0), unrolled with individual params.
* **body** — the remaining ``n_periods × period`` layers.  Params are stacked
  along a leading ``(n_periods,)`` axis and the stack runs under
  ``jax.lax.scan`` (small HLO, fast SPMD partitioning, MaxText-style), with
  ``jax.checkpoint`` on the period body for training remat.  ``period`` is
  ``lcm(len(layer_pattern), moe.every_k_layers)`` so every scan step sees an
  identical layer-kind sequence.

Sharding (see DESIGN.md §5): params FSDP over ``data`` × TP/EP over
``model``; inter-block activations sequence-sharded over ``model`` (Megatron
SP) so the per-device live set stays O(B·S·D/model); the LM-head loss is
computed in sequence chunks against the vocab-parallel embedding, inside a
rematerialized scan — full (B, S, V) logits never exist.
"""

from __future__ import annotations

import dataclasses
import math
import os
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from .. import compat

from .. import flags
from .attention import (gqa_attention, gqa_decode, gqa_init, gqa_specs,
                        mla_attention, mla_decode, mla_init, mla_specs)
from .config import ModelConfig
from .layers import (NO_SHARDING, Params, ShardingRules, constrain,
                     dense_init, embed_init, mlp, mlp_init, mlp_specs,
                     rmsnorm, rmsnorm_init)
from .mamba2 import (_dims as mamba_dims, mamba_decode, mamba_forward,
                     mamba_init, mamba_specs)
from .moe import moe_apply, moe_init, moe_specs


# ---------------------------------------------------------------------- #
# Layer layout: prefix + periodic body
# ---------------------------------------------------------------------- #
def layer_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_prefix, period, n_periods)."""
    n_prefix = 1 if (cfg.moe and cfg.moe.first_dense_d_ff) else 0
    period = len(cfg.layer_pattern)
    if cfg.moe and cfg.moe.every_k_layers > 1:
        period = math.lcm(period, cfg.moe.every_k_layers)
    body = cfg.num_layers - n_prefix
    if body % period:
        raise ValueError(
            f"{cfg.name}: body layers {body} not divisible by period {period}")
    return n_prefix, period, body // period


def _layer_ff(cfg: ModelConfig, i: int) -> Optional[int]:
    """d_ff of the dense FF at layer ``i`` (None if the layer has no FF)."""
    if cfg.is_moe_layer(i):
        return None  # MoE instead
    if cfg.moe and cfg.moe.first_dense_d_ff and i == 0:
        return cfg.moe.first_dense_d_ff
    return cfg.d_ff if cfg.d_ff else None


# ---------------------------------------------------------------------- #
# One block: (attention | mamba) + optional (mlp | moe), pre-norm residual
# ---------------------------------------------------------------------- #
def block_init(key, cfg: ModelConfig, i: int, dtype=jnp.bfloat16) -> Params:
    k1, k2 = jax.random.split(key)
    kind = cfg.layer_kind(i)
    p: Params = {"norm1": rmsnorm_init(cfg.d_model, dtype)}
    if kind == "a":
        p["attn"] = (mla_init(k1, cfg, dtype) if cfg.mla
                     else gqa_init(k1, cfg, dtype))
    else:
        p["mixer"] = mamba_init(k1, cfg, dtype)
    ff = _layer_ff(cfg, i)
    if cfg.is_moe_layer(i):
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        p["moe"] = moe_init(k2, cfg, dtype)
    elif ff:
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        p["mlp"] = mlp_init(k2, cfg.d_model, ff, dtype)
    return p


def block_specs(cfg: ModelConfig, i: int, rules: ShardingRules) -> Params:
    kind = cfg.layer_kind(i)
    s: Params = {"norm1": {"scale": rules.logical(None)}}
    if kind == "a":
        s["attn"] = (mla_specs(cfg, rules) if cfg.mla
                     else gqa_specs(cfg, rules))
    else:
        s["mixer"] = mamba_specs(cfg, rules)
    if cfg.is_moe_layer(i):
        s["norm2"] = {"scale": rules.logical(None)}
        s["moe"] = moe_specs(cfg, rules)
    elif _layer_ff(cfg, i):
        s["norm2"] = {"scale": rules.logical(None)}
        s["mlp"] = mlp_specs(rules)
    return s


def block_apply(params: Params, x: jax.Array, cfg: ModelConfig, i: int,
                positions: jax.Array, rules: ShardingRules, impl: str,
                collect_cache: bool = False, cache_len: Optional[int] = None):
    """Full-sequence block.  Returns (x, aux, cache_entry|None)."""
    kind = cfg.layer_kind(i)
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    cache = None
    if kind == "a":
        with jax.named_scope("attn"):
            if cfg.mla:
                a = mla_attention(params["attn"], h, cfg, positions, rules,
                                  impl)
            else:
                a = gqa_attention(params["attn"], h, cfg, positions, rules,
                                  impl)
        if collect_cache:
            cache = _attn_cache_from_seq(params["attn"], h, cfg, positions,
                                         cache_len, rules)
    else:
        with jax.named_scope("mixer"):
            if collect_cache:
                a, ssm, conv = mamba_forward(params["mixer"], h, cfg, rules,
                                             impl, return_state=True)
                cache = {"ssm": ssm, "conv": conv}
            else:
                a = mamba_forward(params["mixer"], h, cfg, rules, impl)
    x = x + a
    aux = jnp.zeros((), jnp.float32)
    if "moe" in params:
        h2 = rmsnorm(params["norm2"], x, cfg.norm_eps)
        with jax.named_scope("moe"):
            y, aux = moe_apply(params["moe"], h2, cfg, rules)
        x = x + y
    elif "mlp" in params:
        h2 = rmsnorm(params["norm2"], x, cfg.norm_eps)
        with jax.named_scope("mlp"):
            x = x + mlp(params["mlp"], h2, act=cfg.act, rules=rules)
    x = constrain(x, rules, "batch", "model", None)  # SP between blocks
    return x, aux, cache


def _attn_cache_from_seq(attn_p: Params, h: jax.Array, cfg: ModelConfig,
                         positions: jax.Array, cache_len: int,
                         rules: ShardingRules) -> Params:
    """Recompute the K/V (or MLA latent) of a full sequence into a cache."""
    from .layers import apply_rope
    b, s, _ = h.shape
    pad = cache_len - s
    if cfg.mla:
        m = cfg.mla
        ckv = h @ attn_p["w_dkv"]
        c_kv = rmsnorm(attn_p["kv_norm"], ckv[..., :m.kv_lora_rank],
                       cfg.norm_eps)
        k_rope = apply_rope(ckv[..., m.kv_lora_rank:], positions,
                            cfg.rope_theta)
        entry = jnp.concatenate([c_kv, k_rope], axis=-1)
        entry = jnp.pad(entry, ((0, 0), (0, pad), (0, 0)))
        return {"ckv": constrain(entry, rules, "batch", "model", None)}
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = (h @ attn_p["wk"]).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = (h @ attn_p["wv"]).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        k = rmsnorm(attn_p["k_norm"], k, cfg.norm_eps)
    k = apply_rope(k, positions[:, None, :], cfg.rope_theta)
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return {"k": constrain(k, rules, "batch", None, "model", None),
            "v": constrain(v, rules, "batch", None, "model", None)}


def block_decode(params: Params, x: jax.Array, cache: Params,
                 cfg: ModelConfig, i: int, pos: jax.Array,
                 rules: ShardingRules):
    """One-token block step.  x: (B, 1, D).  Returns (x, new_cache)."""
    kind = cfg.layer_kind(i)
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if kind == "a":
        if cfg.mla:
            a, ckv = mla_decode(params["attn"], h, cache["ckv"], pos, cfg,
                                rules)
            new_cache = {"ckv": ckv}
        else:
            a, kc, vc = gqa_decode(params["attn"], h, cache["k"], cache["v"],
                                   pos, cfg, rules)
            new_cache = {"k": kc, "v": vc}
    else:
        a, ssm, conv = mamba_decode(params["mixer"], h, cache["ssm"],
                                    cache["conv"], cfg, rules)
        new_cache = {"ssm": ssm, "conv": conv}
    x = x + a
    if "moe" in params:
        h2 = rmsnorm(params["norm2"], x, cfg.norm_eps)
        y, _ = moe_apply(params["moe"], h2, cfg, rules)
        x = x + y
    elif "mlp" in params:
        h2 = rmsnorm(params["norm2"], x, cfg.norm_eps)
        x = x + mlp(params["mlp"], h2, act=cfg.act, rules=rules)
    return constrain(x, rules, "batch", None, None), new_cache


def block_cache_init(cfg: ModelConfig, i: int, batch: int, cache_len: int,
                     dtype=jnp.bfloat16) -> Params:
    kind = cfg.layer_kind(i)
    if kind == "a":
        if cfg.mla:
            m = cfg.mla
            return {"ckv": jnp.zeros(
                (batch, cache_len, m.kv_lora_rank + m.qk_rope_head_dim),
                dtype)}
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        shape = (batch, hkv, cache_len, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    s, d_in, nh = mamba_dims(cfg)
    return {"ssm": jnp.zeros((batch, nh, s.d_state, s.head_dim), jnp.float32),
            "conv": jnp.zeros((batch, s.d_conv - 1, d_in), dtype)}


def block_cache_specs(cfg: ModelConfig, i: int, rules: ShardingRules) -> Params:
    """Decode caches: KV sequence-sharded over 'model' (head-count agnostic)."""
    kind = cfg.layer_kind(i)
    if kind == "a":
        if cfg.mla:
            return {"ckv": rules.logical("batch", "model", None)}
        kv = rules.logical("batch", None, "model", None)
        return {"k": kv, "v": kv}
    return {"ssm": rules.logical("batch", "model", None, None),
            "conv": rules.logical("batch", None, "model")}


# ---------------------------------------------------------------------- #
# Full model params
# ---------------------------------------------------------------------- #
def init_params(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    n_prefix, period, n_periods = layer_layout(cfg)
    k_embed, k_head, k_layers = jax.random.split(key, 3)

    params: Params = {}
    vp = cfg.padded_vocab
    if cfg.family == "audio":
        params["embed"] = embed_init(
            k_embed, (cfg.num_codebooks, vp, cfg.d_model), dtype)
    else:
        params["embed"] = embed_init(k_embed, (vp, cfg.d_model), dtype)
    if not cfg.tie_embeddings:
        if cfg.family == "audio":
            params["lm_head"] = embed_init(
                k_head, (cfg.num_codebooks, vp, cfg.d_model), dtype)
        else:
            params["lm_head"] = embed_init(k_head, (vp, cfg.d_model), dtype)

    keys = jax.random.split(k_layers, cfg.num_layers)
    params["prefix"] = [block_init(keys[i], cfg, i, dtype)
                        for i in range(n_prefix)]

    def one_period(p_idx):
        return {"layers": [
            block_init(keys[n_prefix + p_idx * period + j],
                       cfg, n_prefix + p_idx * period + j, dtype)
            for j in range(period)]}
    periods = [one_period(p) for p in range(n_periods)]
    params["body"] = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *periods)
    params["final_norm"] = rmsnorm_init(cfg.d_model, dtype)
    return params


def param_specs(cfg: ModelConfig, rules: ShardingRules) -> Params:
    n_prefix, period, n_periods = layer_layout(cfg)
    specs: Params = {}
    # vocab-parallel embedding/unembedding: vocab over 'model', d replicated
    # (a d-over-'data' shard would fight the batch sharding and un-shard the
    # whole residual stream — measured in EXPERIMENTS.md §Perf iter 3)
    if cfg.family == "audio":
        specs["embed"] = rules.logical(None, "model", None)
    else:
        specs["embed"] = rules.logical("model", None)
    if not cfg.tie_embeddings:
        specs["lm_head"] = (rules.logical(None, "model", None)
                            if cfg.family == "audio"
                            else rules.logical("model", None))
    specs["prefix"] = [block_specs(cfg, i, rules) for i in range(n_prefix)]
    one = {"layers": [block_specs(cfg, n_prefix + j, rules)
                      for j in range(period)]}
    # body params have a leading (n_periods,) stack axis
    from jax.sharding import PartitionSpec as P
    specs["body"] = jax.tree_util.tree_map(
        lambda sp: P(*((None,) + tuple(sp))), one,
        is_leaf=lambda x: isinstance(x, P))
    specs["final_norm"] = {"scale": rules.logical(None)}
    return specs


# ---------------------------------------------------------------------- #
# Frontends: tokens -> embeddings
# ---------------------------------------------------------------------- #
def _vp_gather(table: jax.Array, toks: jax.Array,
               rules: ShardingRules) -> jax.Array:
    """Vocab-parallel embedding lookup, Megatron-style.

    GSPMD's gather partitioner replicates the table (a full-table
    all-gather every step, and full-table grad all-reduces in reverse), so
    the masked-local-gather + psum_scatter schedule is written explicitly
    under ``shard_map``: each model rank gathers from its vocab shard,
    out-of-range rows contribute zero, and the reduction lands already
    sequence-sharded (SP).  Reverse-mode gives scatter-add into the local
    shard + all-gather — no table-sized collectives anywhere.
    """
    ms = rules.model_size
    vp, d = table.shape
    b, s = toks.shape
    if (rules.model is None or ms <= 1 or vp % ms or s % ms):
        return jnp.take(table, toks, axis=0)

    def local(tab, tk):
        r = jax.lax.axis_index(rules.model)
        vshard = tab.shape[0]
        lo = r * vshard
        loc = jnp.clip(tk - lo, 0, vshard - 1)
        x = jnp.where(((tk >= lo) & (tk < lo + vshard))[..., None],
                      jnp.take(tab, loc, axis=0), 0)
        # reduce + scatter onto the sequence axis: arrives SP-sharded
        return jax.lax.psum_scatter(x, rules.model, scatter_dimension=1,
                                    tiled=True)

    from jax.sharding import PartitionSpec as P
    return compat.shard_map(
        local,
        in_specs=(P(rules.model, None), P(rules.batch, None)),
        out_specs=P(rules.batch, rules.model, None))(table, toks)


def embed_tokens(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                 rules: ShardingRules) -> Tuple[jax.Array, jax.Array]:
    """Returns (x (B, S, D), positions (B, S))."""
    if cfg.family == "audio":
        toks = batch["tokens"]                     # (B, S, K)
        b, s, k = toks.shape
        # sum of per-codebook embeddings (MusicGen delay pattern is applied
        # by the data stub; the backbone just sums)
        x = sum(_vp_gather(params["embed"][i], toks[..., i], rules)
                for i in range(cfg.num_codebooks))
    elif cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(params["embed"].dtype)
        toks = batch["tokens"]                     # (B, S_text)
        text = _vp_gather(params["embed"], toks, rules)
        x = jnp.concatenate([patches, text], axis=1)
        b, s = x.shape[0], x.shape[1]
    else:
        toks = batch["tokens"]                     # (B, S)
        x = _vp_gather(params["embed"], toks, rules)
        b, s = toks.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return constrain(x, rules, "batch", "model", None), positions


# ---------------------------------------------------------------------- #
# Forward over the stack
# ---------------------------------------------------------------------- #
def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            rules: ShardingRules = NO_SHARDING, impl: str = "auto",
            remat: bool = True, collect_cache: bool = False,
            cache_len: Optional[int] = None):
    """Full-sequence forward.  Returns (h, aux[, caches])."""
    n_prefix, period, n_periods = layer_layout(cfg)
    x, positions = embed_tokens(params, cfg, batch, rules)
    aux_total = jnp.zeros((), jnp.float32)
    prefix_caches = []
    for i, lp in enumerate(params["prefix"]):
        x, aux, cache = block_apply(lp, x, cfg, i, positions, rules, impl,
                                    collect_cache, cache_len)
        aux_total = aux_total + aux
        prefix_caches.append(cache)

    def period_body(x, period_params):
        aux_p = jnp.zeros((), jnp.float32)
        caches = []
        for j in range(period):
            blk = partial(block_apply, cfg=cfg, i=n_prefix + j,
                          positions=positions, rules=rules, impl=impl,
                          collect_cache=collect_cache, cache_len=cache_len)
            if remat and not collect_cache and period > 1 and not os.environ.get('REPRO_NO_NESTED_REMAT'):
                # nested remat: with multi-layer periods (jamba: 8) the
                # period-level checkpoint alone keeps a whole period of
                # activations live — re-checkpoint each block so the peak
                # is one layer (72 GB -> ~15 GB/device on jamba train_4k)
                blk = jax.checkpoint(blk, prevent_cse=False)
            x, aux, cache = blk(period_params["layers"][j], x)
            aux_p = aux_p + aux
            caches.append(cache)
        if collect_cache:
            return x, (aux_p, {"layers": caches})
        return x, aux_p

    body = period_body
    if remat and not collect_cache:
        body = jax.checkpoint(period_body, prevent_cse=False)
    x, scanned = jax.lax.scan(body, x, params["body"],
                              unroll=flags.scan_unroll_layers())
    if collect_cache:
        aux_scan, body_caches = scanned
        caches = {"prefix": prefix_caches, "body": body_caches}
        aux_total = aux_total + jnp.sum(aux_scan)
        h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return h, aux_total, caches
    aux_total = aux_total + jnp.sum(scanned)
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return h, aux_total


# ---------------------------------------------------------------------- #
# Vocab-parallel chunked cross-entropy
# ---------------------------------------------------------------------- #
def _unembed(params: Params, cfg: ModelConfig) -> jax.Array:
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return w  # (Vp, D) or (K, Vp, D)


def _mask_pad_logits(logits: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    return jnp.where(iota < cfg.vocab_size, logits, -1e30)


def chunked_ce_loss(params: Params, cfg: ModelConfig, h: jax.Array,
                    labels: jax.Array, rules: ShardingRules = NO_SHARDING,
                    chunk: int = 512, z_loss: float = 1e-4) -> jax.Array:
    """Mean CE over labels >= 0.  h: (B, S, D); labels: (B, S[, K]).

    The sequence is processed in chunks inside a rematerialized scan so the
    full (B, S, V) logits are never resident; the vocab dimension stays
    sharded over ``model`` end-to-end (lse/gather via masked reductions,
    which GSPMD turns into partial-reduce + psum — no logits all-gather).
    """
    w = _unembed(params, cfg).astype(jnp.bfloat16)
    b, s, d = h.shape
    audio = cfg.family == "audio"
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        lab_pad = ((0, 0), (0, pad)) + (((0, 0),) if audio else ())
        labels = jnp.pad(labels, lab_pad, constant_values=-1)
    # keep h sequence-sharded: the CE cotangent then re-enters the backward
    # layer scan seq-sharded instead of replicated (per-layer AG otherwise)
    h = constrain(h, rules, "batch", "model", None)

    def step(carry, i):
        loss_sum, count = carry
        hs = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, 1)
        ls = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, 1)
        if audio:
            logits = jnp.einsum("bsd,kvd->bskv", hs, w).astype(jnp.float32)
            logits = constrain(logits, rules, "batch", None, None, "model")
        else:
            logits = jnp.einsum("bsd,vd->bsv", hs, w).astype(jnp.float32)
            logits = constrain(logits, rules, "batch", None, "model")
        if cfg.padded_vocab != cfg.vocab_size:  # mask pad rows out of softmax
            pad_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                                logits.ndim - 1)
            logits = jnp.where(pad_iota < cfg.vocab_size, logits, -1e30)
        m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
        lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
        v = logits.shape[-1]
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        ll = jnp.sum(jnp.where(iota == ls[..., None], logits, 0.0), axis=-1)
        valid = ls >= 0
        tok_loss = lse - ll + z_loss * lse ** 2
        loss_sum = loss_sum + jnp.sum(jnp.where(valid, tok_loss, 0.0))
        count = count + jnp.sum(valid)
        return (loss_sum, count), None

    init = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32))
    (loss_sum, count), _ = jax.lax.scan(
        jax.checkpoint(step, prevent_cse=False), init, jnp.arange(n_chunks),
        unroll=flags.scan_unroll_inner())
    return loss_sum / jnp.maximum(count, 1).astype(jnp.float32)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            rules: ShardingRules = NO_SHARDING, impl: str = "auto",
            remat: bool = True, ce_chunk: int = 512) -> Tuple[jax.Array, Dict]:
    """Training loss = chunked CE + MoE aux.  batch must carry 'labels'."""
    h, aux = forward(params, cfg, batch, rules, impl, remat)
    if cfg.family == "vlm":
        n_patch = batch["patch_embeds"].shape[1]
        h = h[:, n_patch:]
    ce = chunked_ce_loss(params, cfg, h, batch["labels"], rules, ce_chunk)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------- #
# Serving: prefill + decode
# ---------------------------------------------------------------------- #
def init_caches(cfg: ModelConfig, batch_size: int, cache_len: int,
                dtype=jnp.bfloat16) -> Params:
    n_prefix, period, n_periods = layer_layout(cfg)
    prefix = [block_cache_init(cfg, i, batch_size, cache_len, dtype)
              for i in range(n_prefix)]
    one = {"layers": [block_cache_init(cfg, n_prefix + j, batch_size,
                                       cache_len, dtype)
                      for j in range(period)]}
    body = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n_periods,) + x.shape), one)
    return {"prefix": prefix, "body": body}


def cache_specs(cfg: ModelConfig, rules: ShardingRules) -> Params:
    from jax.sharding import PartitionSpec as P
    n_prefix, period, n_periods = layer_layout(cfg)
    prefix = [block_cache_specs(cfg, i, rules) for i in range(n_prefix)]
    one = {"layers": [block_cache_specs(cfg, n_prefix + j, rules)
                      for j in range(period)]}
    body = jax.tree_util.tree_map(
        lambda sp: P(*((None,) + tuple(sp))), one,
        is_leaf=lambda x: isinstance(x, P))
    return {"prefix": prefix, "body": body}


def decode_step(params: Params, cfg: ModelConfig, caches: Params,
                tokens: jax.Array, pos: jax.Array,
                rules: ShardingRules = NO_SHARDING
                ) -> Tuple[jax.Array, Params]:
    """One decode step.  tokens: (B, 1) or (B, 1, K) audio; pos: (B,).

    Returns (logits (B, V) or (B, K, V), new caches).
    """
    n_prefix, period, n_periods = layer_layout(cfg)
    if cfg.family == "audio":
        x = sum(jnp.take(params["embed"][i], tokens[..., i], axis=0)
                for i in range(cfg.num_codebooks))
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, rules, "batch", None, None)

    new_prefix = []
    for i, lp in enumerate(params["prefix"]):
        x, nc = block_decode(lp, x, caches["prefix"][i], cfg, i, pos, rules)
        new_prefix.append(nc)

    def step(x, inp):
        pp, cc = inp
        new_cc = []
        for j in range(period):
            x, ncj = block_decode(pp["layers"][j], x, cc["layers"][j], cfg,
                                  n_prefix + j, pos, rules)
            new_cc.append(ncj)
        return x, {"layers": new_cc}

    x, new_body = jax.lax.scan(step, x, (params["body"], caches["body"]),
                               unroll=flags.scan_unroll_layers())
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)[:, 0]   # (B, D)
    w = _unembed(params, cfg).astype(jnp.bfloat16)
    if cfg.family == "audio":
        logits = jnp.einsum("bd,kvd->bkv", h, w).astype(jnp.float32)
        logits = constrain(logits, rules, "batch", None, "model")
    else:
        logits = jnp.einsum("bd,vd->bv", h, w).astype(jnp.float32)
        logits = constrain(logits, rules, "batch", "model")
    return _mask_pad_logits(logits, cfg), {"prefix": new_prefix, "body": new_body}


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            cache_len: int, rules: ShardingRules = NO_SHARDING,
            impl: str = "auto") -> Tuple[jax.Array, Params]:
    """Process a full prompt; returns (last-position logits (B, ...), caches)."""
    h, _, caches = forward(params, cfg, batch, rules, impl, remat=False,
                           collect_cache=True, cache_len=cache_len)
    last = h[:, -1]                                            # (B, D)
    w = _unembed(params, cfg).astype(jnp.bfloat16)
    if cfg.family == "audio":
        logits = jnp.einsum("bd,kvd->bkv", last, w).astype(jnp.float32)
        logits = constrain(logits, rules, "batch", None, "model")
    else:
        logits = jnp.einsum("bd,vd->bv", last, w).astype(jnp.float32)
        logits = constrain(logits, rules, "batch", "model")
    return _mask_pad_logits(logits, cfg), caches

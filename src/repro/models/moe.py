"""Mixture-of-Experts with expert parallelism — the paper's shuffle on the
model critical path.

Token→expert dispatch is a distributed hash-partition-with-capacity exactly
like ``repro.dataframe.shuffle``: rows (tokens) are routed to destination
partitions (experts) under a static per-destination capacity, overflow is
dropped-and-counted, and the data movement is one all-to-all over the mesh.

Two implementations:

* ``moe_apply`` (production) — **sort-based grouped dispatch**, the same
  algorithm as the dataframe shuffle's bucketize step (stable sort by
  destination + rank-within-bucket + capacity drop), vectorized per token
  group.  Peak memory is the (G, E, C, D) expert buffer — the actual data —
  instead of GShard's (T, E, C) one-hot dispatch tensors, which are O(T²)
  per group and unusable at 4k×256 batch.  With the expert axis sharded over
  ``model``, GSPMD lowers the group→expert layout change to the same
  all-to-all collective the dataframe engine issues explicitly.
* ``moe_apply_einsum`` (oracle) — the classic GShard one-hot einsum
  formulation, kept for small-shape parity tests.

Router: softmax top-k with renormalization, load-balance auxiliary loss
(Switch-style), shared experts always-on (DeepSeek-MoE).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from .. import compat

from .config import ModelConfig
from .layers import (NO_SHARDING, Params, ShardingRules, constrain,
                     dense_init, mlp, mlp_init, mlp_specs)


def moe_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    m = cfg.moe
    d, ff = cfg.d_model, m.d_ff_expert
    k_r, k_e, k_s = jax.random.split(key, 3)
    ke = jax.random.split(k_e, 3)
    p = {
        "router": dense_init(k_r, (d, m.num_experts), 0, jnp.float32),
        "experts": {
            "w_gate": dense_init(ke[0], (m.num_experts, d, ff), 1, dtype),
            "w_up": dense_init(ke[1], (m.num_experts, d, ff), 1, dtype),
            "w_down": dense_init(ke[2], (m.num_experts, ff, d), 1, dtype),
        },
    }
    if m.num_shared:
        p["shared"] = mlp_init(k_s, d, ff * m.num_shared, dtype)
    return p


def moe_specs(cfg: ModelConfig, rules: ShardingRules) -> Params:
    m = cfg.moe
    s = {
        "router": rules.logical("fsdp", None),
        "experts": {
            # EP: experts over 'model', other dims replicated — the shuffle
            # dispatch runs under shard_map with these exact in_specs, and
            # the fp32 optimizer moments regain a 'data' dim via
            # ``train.step state_specs`` (2-D ZeRO) so big MoE archs fit.
            "w_gate": rules.logical("model", None, None),
            "w_up": rules.logical("model", None, None),
            "w_down": rules.logical("model", None, None),
        },
    }
    if m.num_shared:
        s["shared"] = mlp_specs(rules)
    return s


def _route(params: Params, x: jax.Array, cfg: ModelConfig
           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Router: (topv, topi, aux_loss).  x: (..., D)."""
    m = cfg.moe
    e, k = m.num_experts, m.top_k
    logits = x.astype(jnp.float32) @ params["router"]          # (..., E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                       # (..., k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch): E * mean(frac_tokens * frac_probs)
    flat_i = topi.reshape(-1, k)
    flat_p = probs.reshape(-1, e)
    onehot_all = jax.nn.one_hot(flat_i, e, dtype=jnp.float32)  # (T, k, E)
    frac_tokens = onehot_all.sum(1).mean(0)
    frac_probs = flat_p.mean(0)
    aux = m.router_aux_weight * e * jnp.sum(frac_tokens * frac_probs)
    return topv, topi, aux


def expert_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    m = cfg.moe
    cap = int(m.capacity_factor * tokens_per_group * m.top_k / m.num_experts)
    return max(8, -(-max(cap, m.top_k) // 8) * 8)


def moe_apply(params: Params, x: jax.Array, cfg: ModelConfig,
              rules: ShardingRules = NO_SHARDING
              ) -> Tuple[jax.Array, jax.Array]:
    """MoE layer dispatcher.  x: (B, S, D) -> (y, aux).

    Under SP training rules the token→expert trip runs through the
    dataframe-engine shuffle inside shard_map (``moe_apply_shuffle``) —
    explicit all-to-alls instead of GSPMD-inferred collectives, which
    otherwise psum a full (B, S·k, D) f32 tensor over 'model' at the
    combine gather (measured 64× the minimal wire bytes; EXPERIMENTS.md
    §Perf cell 2).  Elsewhere (single device, TP decode) the grouped
    GSPMD formulation below is used.
    """
    m = cfg.moe
    b, s, d = x.shape
    if (rules.model is not None and not rules.tp_weights
            and m.num_experts % rules.model_size == 0
            and s % rules.model_size == 0):
        return moe_apply_shuffle(params, x, cfg, rules)
    return moe_apply_grouped(params, x, cfg, rules)


def moe_apply_grouped(params: Params, x: jax.Array, cfg: ModelConfig,
                      rules: ShardingRules = NO_SHARDING
                      ) -> Tuple[jax.Array, jax.Array]:
    """Sort-based grouped capacity dispatch (GSPMD global view).

    Each batch row is a dispatch group (G = B, Tg = S); the shuffle runs
    group-locally so all gathers/scatters stay on the data-sharded batch
    axis, and the only cross-device movement is the (G, E, C, D) buffer's
    group→expert resharding — the MoE all-to-all.
    """
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    cap = expert_capacity(cfg, s)

    x = constrain(x, rules, "batch", None, None)
    topv, topi, aux = _route(params, x, cfg)                   # (B, S, k)

    # --- bucketize (the dataframe-shuffle algorithm, per group) --------- #
    flat_e = topi.reshape(b, s * k)
    order = jnp.argsort(flat_e, axis=1, stable=True)           # (B, S*k)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    # stable rank within expert bucket
    start = jax.vmap(lambda row: jnp.searchsorted(row, row, side="left"))(
        sorted_e)
    rank = jnp.arange(s * k, dtype=jnp.int32)[None] - start.astype(jnp.int32)
    slot = jnp.where(rank < cap, sorted_e * cap + rank, e * cap)
    token_of = (order // k).astype(jnp.int32)                  # source token

    # send buffer: buf_src[slot] = source token index (sentinel s => zeros)
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    buf_src = jnp.full((b, e * cap), s, jnp.int32)
    buf_src = buf_src.at[rows, slot].set(token_of, mode="drop")

    x_pad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    ex_in = jnp.take_along_axis(x_pad, buf_src[..., None], axis=1)
    ex_in = ex_in.reshape(b, e, cap, d)
    # group→expert resharding: THE all-to-all (experts sharded over 'model')
    ex_in = constrain(ex_in, rules, "batch", "model", None, None)

    w = params["experts"]
    h_g = jnp.einsum("becd,edf->becf", ex_in, w["w_gate"])
    h_u = jnp.einsum("becd,edf->becf", ex_in, w["w_up"])
    h = jax.nn.silu(h_g.astype(jnp.float32)).astype(x.dtype) * h_u
    ex_out = jnp.einsum("becf,efd->becd", h, w["w_down"])
    ex_out = constrain(ex_out, rules, "batch", "model", None, None)

    # --- combine: expert→group return trip ------------------------------ #
    inv = jnp.argsort(order, axis=1)                           # flat -> sorted
    my_slot = jnp.take_along_axis(slot, inv, axis=1)           # (B, S*k)
    out_pad = jnp.concatenate(
        [ex_out.reshape(b, e * cap, d),
         jnp.zeros((b, 1, d), ex_out.dtype)], axis=1)
    idx = jnp.minimum(my_slot, e * cap)                        # dropped -> 0row
    vals = jnp.take_along_axis(out_pad, idx[..., None], axis=1)  # (B, S*k, D)
    y = (vals.reshape(b, s, k, d)
         * topv.reshape(b, s, k, 1).astype(vals.dtype)).sum(axis=2)
    y = constrain(y, rules, "batch", None, None)

    if m.num_shared:
        y = y + mlp(params["shared"], x, act="silu", rules=rules)
    return y, aux


def moe_apply_shuffle(params: Params, x: jax.Array, cfg: ModelConfig,
                      rules: ShardingRules) -> Tuple[jax.Array, jax.Array]:
    """Token dispatch through the dataframe-engine shuffle (shard_map).

    This IS the paper's mechanism on the model's critical path: each
    (data, model) shard owns its sequence slice of tokens (SP), routes
    (token-vector, local-expert-id, provenance) rows to expert-owning ranks
    with the capacity-based all-to-all ``repro.dataframe.shuffle``, runs the
    expert FFN as the *core local operator*, and shuffles results back by
    provenance — two explicit all-to-alls of exactly the dispatched rows,
    instead of GSPMD-inferred full-tensor all-reduces.
    """
    from ..comm import get_communicator
    from ..dataframe.shuffle import shuffle as df_shuffle
    from ..dataframe.table import Table
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    ms = rules.model_size
    e_loc = e // ms
    axis = rules.model
    b_axes = rules.batch if isinstance(rules.batch, tuple) else (rules.batch,)
    all_axes = tuple(a for a in b_axes if a) + (axis,)
    x = constrain(x, rules, "batch", "model", None)

    def body(xl, router, wg, wu, wd):
        # xl: (b_l, s_l, d); router: (d, E); wg/wu/wd: (e_loc, d|f, ...)
        # the paper's modular communicator, on the model's critical path:
        # the dispatch all-to-alls run on whichever collective schedule the
        # config selects (xla = native, ring = Gloo-analogue, bruck = UCC)
        comm = get_communicator(m.communicator, axis)
        r = comm.rank()
        b_l, s_l, _ = xl.shape
        t = b_l * s_l
        xt = xl.reshape(t, d)

        # --- route (local tokens) ---------------------------------------- #
        logits = xt.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = jax.lax.top_k(probs, k)                  # (t, k)
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
        # global load-balance aux (partials psummed over every sharded axis)
        onehot = jax.nn.one_hot(topi.reshape(-1), e, dtype=jnp.float32)
        tok_sum = jax.lax.psum(onehot.sum(0), all_axes)
        prob_sum = jax.lax.psum(probs.sum(0), all_axes)
        n_tok = jax.lax.psum(jnp.float32(t), all_axes)
        aux = m.router_aux_weight * e * jnp.sum(
            (tok_sum / (n_tok * k)) * (prob_sum / n_tok)) * k

        # --- outbound shuffle: rows = (x-vector, local expert, provenance) #
        tk = t * k
        flat_e = topi.reshape(tk)
        dest = (flat_e // e_loc).astype(jnp.int32)            # owning rank
        rows = Table({
            "x": jnp.repeat(xt, k, axis=0),                   # (t*k, d)
            "eloc": (flat_e % e_loc).astype(jnp.int32),
            "srcslot": jnp.arange(tk, dtype=jnp.int32),
            "src": jnp.full((tk,), r, jnp.int32),
        }, jnp.asarray(tk, jnp.int32))
        cap_send = max(8, -(-int(m.capacity_factor * tk) // (8 * ms)) * 8)
        recv, stats = df_shuffle(rows, comm, dest=dest,
                                 bucket_capacity=cap_send,
                                 out_capacity=ms * cap_send)

        # --- core local operator: group by local expert, batched FFN ----- #
        rcap = ms * cap_send
        valid = recv.valid_mask()
        eloc = jnp.where(valid, recv.col("eloc"), e_loc)
        order = jnp.argsort(eloc, stable=True)
        sorted_e = jnp.take(eloc, order)
        start = jnp.searchsorted(sorted_e, sorted_e, side="left")
        rank = jnp.arange(rcap, dtype=jnp.int32) - start.astype(jnp.int32)
        # per-local-expert capacity: 2x the balanced share, never more than
        # the total rows that can arrive (tight when e_loc == 1)
        cap2 = min(max(8, -(-int(rcap * 2) // (8 * e_loc)) * 8),
                   -(-rcap // 8) * 8)
        slot = jnp.where((sorted_e < e_loc) & (rank < cap2),
                         sorted_e * cap2 + rank, e_loc * cap2)
        xs = jnp.take(recv.col("x"), order, axis=0)           # (rcap, d)
        buf = jnp.zeros((e_loc * cap2, d), xs.dtype)
        buf = buf.at[slot].set(xs, mode="drop")
        ex_in = buf.reshape(e_loc, cap2, d)
        h_g = jnp.einsum("ecd,edf->ecf", ex_in, wg)
        h_u = jnp.einsum("ecd,edf->ecf", ex_in, wu)
        h = jax.nn.silu(h_g.astype(jnp.float32)).astype(xs.dtype) * h_u
        ex_out = jnp.einsum("ecf,efd->ecd", h, wd).reshape(e_loc * cap2, d)

        # un-group: value for each received row (dropped-by-cap2 -> zero)
        out_pad = jnp.concatenate(
            [ex_out, jnp.zeros((1, d), ex_out.dtype)], axis=0)
        vals_sorted = jnp.take(out_pad, jnp.minimum(slot, e_loc * cap2),
                               axis=0)
        inv = jnp.argsort(order)
        vals = jnp.take(vals_sorted, inv, axis=0)             # recv order

        # --- return shuffle by provenance -------------------------------- #
        back_tbl = Table({
            "y": vals,
            "srcslot": recv.col("srcslot"),
        }, recv.row_count)
        back_dest = jnp.where(valid, recv.col("src"), ms)
        back, _ = df_shuffle(back_tbl, comm, dest=back_dest,
                             bucket_capacity=cap_send,
                             out_capacity=tk)

        # --- combine at the source ---------------------------------------#
        y_rows = jnp.zeros((tk + 1, d), xl.dtype)
        bslot = jnp.where(back.valid_mask(), back.col("srcslot"), tk)
        y_rows = y_rows.at[bslot].set(
            back.col("y").astype(xl.dtype), mode="drop")[:tk]
        y = (y_rows.reshape(t, k, d)
             * topv.reshape(t, k, 1).astype(xl.dtype)).sum(axis=1)
        return y.reshape(b_l, s_l, d), aux[None]

    bspec = rules.batch
    y, aux = compat.shard_map(
        body,
        in_specs=(P(bspec, axis, None), P(), P(axis, None, None),
                  P(axis, None, None), P(axis, None, None)),
        out_specs=(P(bspec, axis, None), P(None)),
        check_vma=False,
    )(x, params["router"], params["experts"]["w_gate"],
      params["experts"]["w_up"], params["experts"]["w_down"])
    aux = aux.reshape(-1)[0]

    if m.num_shared:
        y = y + mlp(params["shared"], x, act="silu", rules=rules)
    return y, aux


def moe_apply_einsum(params: Params, x: jax.Array, cfg: ModelConfig,
                     rules: ShardingRules = NO_SHARDING
                     ) -> Tuple[jax.Array, jax.Array]:
    """GShard one-hot einsum dispatch (oracle for small shapes).

    Capacity ranks are computed per batch-row group so drop behaviour
    matches ``moe_apply`` exactly.
    """
    m = cfg.moe
    b, s, d = x.shape
    t = s                                        # tokens per group
    e, k = m.num_experts, m.top_k
    cap = expert_capacity(cfg, s)

    topv, topi, aux = _route(params, x, cfg)     # (B, S, k)

    flat_e = topi.reshape(b, t * k)
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)            # (B, T*k, E)
    # stable rank of each (token, choice) within its expert queue.  Ties
    # between the k choices of one token resolve by expert id (sort order in
    # moe_apply), which one_hot cumsum reproduces since each row has one hit.
    rank = (jnp.cumsum(oh, axis=1) - oh)[
        rows_b := jnp.arange(b)[:, None], jnp.arange(t * k)[None], flat_e]
    keep = rank < cap
    slot_oh = jax.nn.one_hot(jnp.where(keep, rank, cap), cap, dtype=x.dtype)
    exp_oh = jax.nn.one_hot(flat_e, e, dtype=x.dtype)
    disp_tk = exp_oh[..., None] * slot_oh[..., None, :]        # (B,T*k,E,C)
    disp = disp_tk.reshape(b, t, k, e, cap).sum(2)             # (B,T,E,C)
    comb = (disp_tk * topv.reshape(b, t * k)[..., None, None]
            ).reshape(b, t, k, e, cap).sum(2)

    ex_in = jnp.einsum("btec,btd->becd", disp, x)
    ex_in = constrain(ex_in, rules, "batch", "model", None, None)
    w = params["experts"]
    h_g = jnp.einsum("becd,edf->becf", ex_in, w["w_gate"])
    h_u = jnp.einsum("becd,edf->becf", ex_in, w["w_up"])
    h = jax.nn.silu(h_g.astype(jnp.float32)).astype(x.dtype) * h_u
    ex_out = jnp.einsum("becf,efd->becd", h, w["w_down"])
    y = jnp.einsum("btec,becd->btd", comb, ex_out)

    if m.num_shared:
        y = y + mlp(params["shared"], x, act="silu", rules=rules)
    return y, aux

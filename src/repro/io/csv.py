"""Chunked CSV ingest: streamed blocks -> round-robin ``SpillTable``.

Two lanes share the ``TableBuilder`` (so partitioning, dictionary growth,
and null handling are byte-identical):

* **pyarrow lane** (default when pyarrow is importable and
  ``REPRO_NO_PYARROW`` is unset): ``pyarrow.csv.open_csv`` streams
  ``block_bytes``-sized record batches with Arrow's type inference;
  ``strings_can_be_null=True`` so an empty field is null in *every* column
  type, matching the fallback lane.
* **pure-python lane**: the stdlib ``csv`` module, ``batch_rows`` rows at
  a time.  Column kinds (numeric vs string) are inferred from the first
  block that has data; int64 quietly widens to float64 across blocks
  (``TableBuilder`` unifies at finalize).  Empty field = null.

The fallback keeps CSV ingest working in minimal environments — CI runs
the ingest suite in both lanes.
"""

from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.store import SpillTable
from .ingest import (DICT_CACHE, DictionaryCache, IngestInfo, TableBuilder,
                     arrow_batch_columns, expand_paths, have_pyarrow,
                     source_key)

__all__ = ["read_csv"]

#: fallback lane: rows per streamed block
DEFAULT_BATCH_ROWS = 65536
#: pyarrow lane: bytes per streamed block
DEFAULT_BLOCK_BYTES = 1 << 20


# ---------------------------------------------------------------------- #
# pure-python fallback lane
# ---------------------------------------------------------------------- #
def _infer_kinds(header: Sequence[str], rows: Sequence[Sequence[str]]
                 ) -> Dict[str, Optional[str]]:
    """Column kind from the first block: "num" if every non-empty value
    parses as a number, "str" otherwise, None if the column was all-empty
    (decided by a later block, or all-null string at finalize)."""
    kinds: Dict[str, Optional[str]] = {}
    for j, name in enumerate(header):
        kind: Optional[str] = None
        for r in rows:
            v = r[j]
            if v == "":
                continue
            try:
                float(v)
                kind = kind or "num"
            except ValueError:
                kind = "str"
                break
        kinds[name] = kind
    return kinds


def _convert_block(header: Sequence[str], rows: List[Sequence[str]],
                   kinds: Dict[str, Optional[str]]
                   ) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    """One parsed block -> (cols, valids) for the builder.  Numeric
    columns parse int-first (so integer CSVs stay int64); any float value
    makes the block float64 and the builder widens the rest at finalize."""
    cols: Dict[str, np.ndarray] = {}
    valids: Dict[str, np.ndarray] = {}
    n = len(rows)
    for j, name in enumerate(header):
        kind = kinds[name]
        if kind is None:
            # still undecided: upgrade from this block if it has data
            kind = _infer_kinds([name], [(r[j],) for r in rows])[name]
            kinds[name] = kind
        raw = [r[j] for r in rows]
        valid = np.fromiter((v != "" for v in raw), dtype=bool, count=n)
        if kind == "str" or kind is None:
            arr = np.asarray(raw, dtype=object)
        else:
            vals: List = []
            for v in raw:
                if v == "":
                    vals.append(0)
                    continue
                try:
                    vals.append(int(v))
                except ValueError:
                    try:
                        vals.append(float(v))
                    except ValueError:
                        raise TypeError(
                            f"column {name!r} mixes numbers with {v!r}; "
                            f"CSV columns must keep one type (the pyarrow "
                            f"lane reports the offending row)") from None
            arr = np.asarray(vals)
            if arr.dtype.kind not in "if":
                arr = arr.astype(np.float64)
        cols[name] = arr
        if not valid.all():
            valids[name] = valid
    return cols, valids


def _read_csv_python(files: Sequence[str], builder: TableBuilder,
                     batch_rows: int) -> int:
    """Stream files through the stdlib csv reader; returns batch count."""
    import csv as _csv
    batches = 0
    header: Optional[List[str]] = None
    kinds: Optional[Dict[str, Optional[str]]] = None
    for f in files:
        with open(f, newline="") as fh:
            rdr = _csv.reader(fh)
            h = next(rdr, None)
            if h is None:
                continue
            if header is None:
                header = list(h)
            elif list(h) != header:
                raise ValueError(
                    f"{f!r} header {h} != first file's header {header}")
            block: List[Sequence[str]] = []
            for row in rdr:
                if len(row) != len(header):
                    raise ValueError(
                        f"{f!r}: row with {len(row)} fields, expected "
                        f"{len(header)}")
                block.append(row)
                if len(block) >= batch_rows:
                    if kinds is None:
                        kinds = _infer_kinds(header, block)
                    builder.add_batch(*_convert_block(header, block, kinds))
                    batches += 1
                    block = []
            if block:
                if kinds is None:
                    kinds = _infer_kinds(header, block)
                builder.add_batch(*_convert_block(header, block, kinds))
                batches += 1
    return batches


# ---------------------------------------------------------------------- #
# pyarrow lane
# ---------------------------------------------------------------------- #
def _read_csv_arrow(files: Sequence[str], builder: TableBuilder,
                    block_bytes: int) -> int:
    import pyarrow.csv as pacsv
    batches = 0
    ropts = pacsv.ReadOptions(block_size=max(1 << 10, block_bytes))
    copts = pacsv.ConvertOptions(strings_can_be_null=True)
    for f in files:
        with pacsv.open_csv(f, read_options=ropts,
                            convert_options=copts) as reader:
            for batch in reader:
                if batch.num_rows == 0:
                    continue
                cols, valids = arrow_batch_columns(batch)
                builder.add_batch(cols, valids)
                batches += 1
    return batches


def read_csv(source: Union[str, os.PathLike, Sequence],
             parallelism: int, *,
             batch_rows: int = DEFAULT_BATCH_ROWS,
             block_bytes: int = DEFAULT_BLOCK_BYTES,
             dict_cache: Optional[DictionaryCache] = DICT_CACHE
             ) -> SpillTable:
    """Read CSV file(s) (with a header row) into a round-robin
    ``SpillTable``.

    ``source`` is a path, a glob, or a list of either (expanded sorted);
    all files must share the header.  Empty fields are null in every
    column type (``__m_*`` masks, canonical-zero slots).  The pyarrow
    streaming reader is used when available (``block_bytes`` per batch);
    otherwise a pure-python lane streams ``batch_rows`` rows at a time.
    ``dict_cache`` works as in ``read_parquet``.
    """
    files = expand_paths(source)
    key = None
    cached = None
    if dict_cache is not None:
        key = source_key(files)
        cached = dict_cache.get(key)
    builder = TableBuilder(parallelism, cached_dicts=cached)
    if have_pyarrow():
        batches = _read_csv_arrow(files, builder, block_bytes)
    else:
        batches = _read_csv_python(files, builder, batch_rows)
    spill = builder.finalize()
    if dict_cache is not None and builder._string_cols:
        dict_cache.put(key, spill.dictionaries)
    spill.provenance = IngestInfo(
        format="csv", files=files, rows=builder.rows,
        bytes_read=sum(os.path.getsize(f) for f in files), batches=batches,
        recodes=builder.recodes, dict_cache_hit=cached is not None)
    return spill

"""Chunked Parquet ingest: row-group batches -> round-robin ``SpillTable``.

``read_parquet`` streams each file's row groups through
``pyarrow.parquet.ParquetFile.iter_batches`` — one batch of at most
``batch_rows`` rows is resident at a time, so a multi-file dataset larger
than device memory ingests straight into the out-of-core spill format
(``docs/out_of_core.md``) and runs under ``collect(morsel_rows=...)``.

Requires pyarrow (``requirements-dev.txt`` optional extra); ``read_csv``
has a dependency-free fallback lane, Parquet does not.
"""

from __future__ import annotations

import os
from typing import Optional, Sequence, Union

from ..core.store import SpillTable
from .ingest import (DICT_CACHE, DictionaryCache, IngestInfo, TableBuilder,
                     arrow_batch_columns, expand_paths, have_pyarrow,
                     source_key)

__all__ = ["read_parquet"]

#: default rows per streamed batch (and thus per spill chunk)
DEFAULT_BATCH_ROWS = 65536


def _require_pyarrow():
    if not have_pyarrow():
        raise ImportError(
            "read_parquet requires pyarrow (optional extra; see "
            "requirements-dev.txt). CSV ingest works without it: "
            "repro.io.read_csv falls back to a pure-python reader.")
    import pyarrow.parquet as pq
    return pq


def _empty_table(pq, files, parallelism: int,
                 columns: Optional[Sequence[str]]) -> SpillTable:
    """Zero-row dataset: keep the file schema (string cols as int32 codes
    over the ``("",)`` convention dictionary) so downstream plans compile."""
    import numpy as np
    import pyarrow as pa
    sch = pq.ParquetFile(files[0]).schema_arrow
    schema = {}
    dicts = {}
    for field in sch:
        if columns is not None and field.name not in columns:
            continue
        if pa.types.is_string(field.type) or \
                pa.types.is_large_string(field.type):
            schema[field.name] = (np.dtype(np.int32), ())
            dicts[field.name] = ("",)
        else:
            schema[field.name] = (np.dtype(field.type.to_pandas_dtype()), ())
    return SpillTable(parallelism, schema=schema, dictionaries=dicts)


def read_parquet(source: Union[str, os.PathLike, Sequence],
                 parallelism: int, *,
                 batch_rows: int = DEFAULT_BATCH_ROWS,
                 columns: Optional[Sequence[str]] = None,
                 dict_cache: Optional[DictionaryCache] = DICT_CACHE
                 ) -> SpillTable:
    """Read Parquet file(s) into a round-robin ``SpillTable``.

    ``source`` is a path, a glob, or a list of either (expanded sorted).
    ``columns`` projects at the reader (only those columns are decoded
    from the file).  ``dict_cache`` seeds string dictionaries from a prior
    read of the same unchanged source (pass ``None`` to disable); the
    returned table's ``provenance`` is an ``IngestInfo`` whose ``recodes``
    counts stale-dictionary chunk recodes (0 on a cache hit).

    Nulls become ``__m_*`` validity masks with canonical-zero data slots
    (``docs/data_model.md``); int/bool columns keep their dtype (no float
    widen at ingest).
    """
    pq = _require_pyarrow()
    files = expand_paths(source)
    key = None
    cached = None
    if dict_cache is not None:
        key = source_key(files)
        cached = dict_cache.get(key)
    builder = TableBuilder(parallelism, cached_dicts=cached)
    batches = 0
    bytes_read = 0
    for f in files:
        pf = pq.ParquetFile(f)
        for batch in pf.iter_batches(batch_size=max(1, batch_rows),
                                     columns=list(columns) if columns
                                     else None):
            if batch.num_rows == 0:
                continue
            cols, valids = arrow_batch_columns(batch)
            builder.add_batch(cols, valids)
            batches += 1
        bytes_read += os.path.getsize(f)
    spill = builder.finalize()
    if builder.rows == 0:
        spill = _empty_table(pq, files, parallelism, columns)
    if dict_cache is not None and builder._string_cols:
        dict_cache.put(key, spill.dictionaries)
    spill.provenance = IngestInfo(
        format="parquet", files=files, rows=builder.rows,
        bytes_read=bytes_read, batches=batches, recodes=builder.recodes,
        dict_cache_hit=cached is not None)
    return spill

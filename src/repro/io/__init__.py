"""``repro.io`` — Arrow-native file ingest into the engine's spill format.

``read_parquet`` / ``read_csv`` stream file batches (Parquet row groups,
CSV blocks) straight into a round-robin-partitioned ``SpillTable`` — the
out-of-core representation of a distributed table — so datasets larger
than device memory ingest without ever materializing a whole file, and
feed ``collect(morsel_rows=...)`` morsel pipelines directly.  String
columns go through the dictionary encoder with incremental dictionary
growth; a process-level ``DictionaryCache`` (keyed by source paths +
sizes + mtimes) makes a repeat read of an unchanged source recode-free.
Missing values become ``__m_*`` validity masks (``repro.nulls``).

Frontend sugar lives in ``repro.df`` (``rdf.read_parquet(...)`` returns a
lazy DataFrame); this package is the table-level API.  See ``docs/io.md``.
"""

from .csv import read_csv
from .ingest import (DICT_CACHE, DictionaryCache, IngestInfo, TableBuilder,
                     have_pyarrow)
from .parquet import read_parquet

__all__ = ["read_parquet", "read_csv", "IngestInfo", "DictionaryCache",
           "DICT_CACHE", "TableBuilder", "have_pyarrow"]

"""Shared ingest machinery behind ``read_parquet`` / ``read_csv``.

The file readers (``repro.io.parquet`` / ``repro.io.csv``) are thin loops:
they open a source, pull one *batch* of rows at a time (a Parquet row-group
slice, a CSV block), and hand each batch to the ``TableBuilder`` here.  The
builder owns everything format-independent:

* **round-robin partitioning** — batch ``i`` lands in rank ``i % p``'s
  bucket, so a multi-file dataset spreads evenly over the gang without a
  shuffle and without ever concatenating the whole table on the host;
* **incremental dictionary encoding** — string columns are encoded against
  a *running* sorted dictionary that grows as new values appear.  Each
  chunk records which dictionary snapshot it was encoded under; at
  ``finalize`` the (few) chunks encoded under a stale snapshot are recoded
  onto the final dictionary with a static gather table
  (``schema.recode_mapping`` — order-preserving, so codes stay sorted);
* **validity masks** — readers report per-batch null masks; the builder
  canonicalizes null slots to the column's zero value and attaches
  ``__m_*`` companions (``repro.nulls``) on every chunk of a column that
  was ever null, so chunk schemas stay uniform;
* **numeric widening** — a column that arrives int64 in one batch and
  float64 in another (CSV fallback lane) is unified to float64 at
  ``finalize``.

``DictionaryCache`` is the process-level cache keyed by the *source
signature* (paths + sizes + mtimes): a second read of an unchanged source
starts from its final dictionaries, so every chunk is encoded against the
complete dictionary up front and ``finalize`` performs **zero recodes**
(``IngestInfo.recodes == 0`` — asserted by the multi-device parity script).
"""

from __future__ import annotations

import dataclasses
import os
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.store import SpillTable
from ..dataframe.schema import (CODE_DTYPE, Dictionary, _as_str_array,
                                recode_mapping)
from ..nulls import check_reserved_names, mask_name

__all__ = ["IngestInfo", "DictionaryCache", "DICT_CACHE", "TableBuilder",
           "source_key", "expand_paths", "have_pyarrow"]


def have_pyarrow() -> bool:
    """True when the pyarrow lane is usable: the package imports and the
    ``REPRO_NO_PYARROW`` escape hatch (CI's no-arrow lane) is not set."""
    if os.environ.get("REPRO_NO_PYARROW", "") not in ("", "0"):
        return False
    try:
        import pyarrow  # noqa: F401
    except ImportError:
        return False
    return True


def expand_paths(source: Union[str, "os.PathLike", Sequence]
                 ) -> Tuple[str, ...]:
    """Normalize a source spec to a sorted tuple of existing file paths.

    Accepts a single path, a glob pattern, or a list of either; globs
    expand sorted so multi-file datasets ingest in a deterministic order.
    """
    import glob as _glob
    if isinstance(source, (str, os.PathLike)):
        source = [source]
    files: List[str] = []
    for s in source:
        s = os.fspath(s)
        if any(ch in s for ch in "*?["):
            hits = sorted(_glob.glob(s))
            if not hits:
                raise FileNotFoundError(f"glob {s!r} matched no files")
            files.extend(hits)
        else:
            if not os.path.exists(s):
                raise FileNotFoundError(f"no such file: {s!r}")
            files.append(s)
    if not files:
        raise FileNotFoundError("empty source list")
    return tuple(files)


def source_key(files: Sequence[str]) -> Tuple:
    """Content signature of a file set: (path, size, mtime_ns) per file.

    A rewritten file changes its size or mtime, so a stale cache entry can
    never be replayed against changed data.
    """
    return tuple((os.path.abspath(f), os.path.getsize(f),
                  os.stat(f).st_mtime_ns) for f in files)


@dataclasses.dataclass(frozen=True)
class IngestInfo:
    """Provenance of an ingested ``SpillTable`` (``spill.provenance``).

    ``scan_read_stats`` (planner) reads ``bytes_read`` to attribute ingest
    volume to the query's scan stage; EXPLAIN renders ``summary()``.
    """

    format: str                   # "parquet" | "csv"
    files: Tuple[str, ...]
    rows: int
    bytes_read: int               # total source bytes consumed
    batches: int                  # chunks streamed through the builder
    recodes: int                  # stale-dictionary chunk recodes at finalize
    dict_cache_hit: bool = False

    def summary(self) -> str:
        return (f"{self.format}: {len(self.files)} "
                f"file{'s' if len(self.files) != 1 else ''}, "
                f"~{self.rows} rows")

    def __str__(self) -> str:
        return self.summary()


class DictionaryCache:
    """Process-level LRU of final ingest dictionaries, keyed by source.

    ``get``/``put`` are thread-safe; ``hits``/``misses`` feed tests and the
    ingest benchmark.  Capped (LRU) so long-lived services do not leak one
    entry per dataset ever read.
    """

    def __init__(self, max_entries: int = 64):
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Tuple, Dict[str, Dictionary]]" = \
            OrderedDict()

    def get(self, key: Tuple) -> Optional[Dict[str, Dictionary]]:
        with self._lock:
            dicts = self._entries.get(key)
            if dicts is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return dict(dicts)

    def put(self, key: Tuple, dicts: Dict[str, Dictionary]) -> None:
        with self._lock:
            self._entries[key] = dict(dicts)
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = self.misses = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


#: the process-level cache ``read_parquet`` / ``read_csv`` use by default
DICT_CACHE = DictionaryCache()


def arrow_batch_columns(batch) -> Tuple[Dict[str, np.ndarray],
                                        Dict[str, np.ndarray]]:
    """Convert a ``pyarrow.RecordBatch`` to ``(cols, valids)`` for
    ``TableBuilder.add_batch``.

    Numeric/bool columns keep their dtype (nulls filled with the canonical
    zero via Arrow's validity bitmap, never a float widen); string columns
    come out as object arrays with null slots holding a placeholder ``""``
    (the builder excludes them from the dictionary and zeroes their codes).
    """
    import pyarrow as pa
    cols: Dict[str, np.ndarray] = {}
    valids: Dict[str, np.ndarray] = {}
    for name, col in zip(batch.schema.names, batch.columns):
        t = col.type
        nulls = col.null_count
        valid = None
        if nulls:
            valid = np.invert(np.asarray(col.is_null()))
        if pa.types.is_string(t) or pa.types.is_large_string(t):
            arr = np.asarray(col.to_pylist(), dtype=object)
            if valid is not None:
                arr[~valid] = ""
        elif pa.types.is_null(t):
            # a column Arrow could not type (e.g. all-empty CSV fields):
            # all-null string, same convention as the catalog
            arr = np.asarray([""] * len(col), dtype=object)
            valid = np.zeros((len(col),), bool)
        elif (pa.types.is_integer(t) or pa.types.is_floating(t)
              or pa.types.is_boolean(t)):
            filled = col if not nulls else pa.compute.fill_null(
                col, pa.scalar(False if pa.types.is_boolean(t) else 0,
                               type=t))
            arr = filled.to_numpy(zero_copy_only=False)
        else:
            raise TypeError(
                f"column {name!r} has unsupported Arrow type {t}; "
                f"supported: integer, floating, boolean, string")
        cols[name] = arr
        if valid is not None:
            valids[name] = valid
    return cols, valids


class _Chunk:
    """One streamed batch, held until finalize (schema may still evolve)."""

    __slots__ = ("cols", "valid", "dictver")

    def __init__(self, cols: Dict[str, np.ndarray],
                 valid: Dict[str, np.ndarray],
                 dictver: Dict[str, int]):
        self.cols = cols          # name -> data (codes for string columns)
        self.valid = valid        # name -> bool mask, only if batch had nulls
        self.dictver = dictver    # string col -> dictionary snapshot index


class TableBuilder:
    """Accumulate streamed batches into a round-robin ``SpillTable``.

    Call ``add_batch`` once per streamed batch, then ``finalize`` once.
    ``cached_dicts`` seeds the running dictionaries (DictionaryCache hit);
    when the seed already covers every value, no chunk is ever recoded.
    """

    def __init__(self, parallelism: int,
                 cached_dicts: Optional[Dict[str, Dictionary]] = None):
        if parallelism < 1:
            raise ValueError(f"parallelism must be >= 1, got {parallelism}")
        self.parallelism = parallelism
        self.rows = 0
        self.recodes = 0
        self._chunks: List[_Chunk] = []
        self._names: Optional[Tuple[str, ...]] = None
        self._string_cols: set = set()
        self._nullable: set = set()
        # running dictionary per string column + its snapshot history
        self._dicts: Dict[str, np.ndarray] = {
            k: np.asarray(v, dtype=str)
            for k, v in (cached_dicts or {}).items()}
        self._snapshots: Dict[str, List[Tuple[str, ...]]] = {
            k: [tuple(v)] for k, v in (cached_dicts or {}).items()}

    # -- streaming ------------------------------------------------------- #
    def _encode_strings(self, name: str, arr: np.ndarray,
                        valid: Optional[np.ndarray]) -> np.ndarray:
        """Encode one batch against the running dictionary, growing it by
        the batch's new values (null slots never enter the dictionary)."""
        arr = _as_str_array(arr, name=repr(name))
        vals = arr if valid is None else arr[valid]
        d = self._dicts.get(name)
        if d is None:
            d = np.zeros((0,), dtype=str)
        if len(vals):
            uniq = np.unique(vals)
            if len(d):
                pos = np.searchsorted(d, uniq)
                pos = np.minimum(pos, len(d) - 1)
                novel = uniq[d[pos] != uniq]
            else:
                novel = uniq
            if len(novel):
                d = np.union1d(d, novel)
                self._dicts[name] = d
                self._snapshots.setdefault(name, []).append(
                    tuple(str(v) for v in d))
        if name not in self._snapshots:
            # first batch and it was all-null: snapshot the empty dict so
            # the chunk still records a version
            self._snapshots[name] = [tuple(str(v) for v in d)]
            self._dicts[name] = d
        if len(d) == 0:
            return np.zeros((len(arr),), CODE_DTYPE)
        codes = np.searchsorted(d, arr)
        codes = np.minimum(codes, len(d) - 1).astype(CODE_DTYPE)
        if valid is not None:
            codes[~valid] = 0     # canonical zero for null slots
        return codes

    def add_batch(self, cols: Dict[str, np.ndarray],
                  valids: Optional[Dict[str, np.ndarray]] = None) -> None:
        """Ingest one batch.  ``cols`` maps names to 1-D arrays (string
        columns as str/object arrays); ``valids`` maps a *subset* of names
        to boolean validity masks (absent = batch has no nulls there).
        Null slots of masked columns may hold arbitrary placeholder values
        — the builder canonicalizes them.
        """
        valids = dict(valids or {})
        names = tuple(cols)
        check_reserved_names(names)
        if self._names is None:
            self._names = names
            from ..dataframe.schema import is_string_array
            self._string_cols = {n for n, a in cols.items()
                                 if is_string_array(np.asarray(a))}
        elif set(names) != set(self._names):
            raise ValueError(
                f"batch schema {sorted(names)} != ingest schema "
                f"{sorted(self._names)} (all files of one read must agree)")
        n = len(next(iter(cols.values())))
        out_cols: Dict[str, np.ndarray] = {}
        out_valid: Dict[str, np.ndarray] = {}
        dictver: Dict[str, int] = {}
        for name in self._names:
            arr = np.asarray(cols[name])
            if len(arr) != n:
                raise ValueError(
                    f"column {name!r} length {len(arr)} != {n}")
            valid = valids.get(name)
            if valid is not None:
                valid = np.asarray(valid).astype(bool)
                if valid.all():
                    valid = None
            if name in self._string_cols:
                out_cols[name] = self._encode_strings(name, arr, valid)
                dictver[name] = len(self._snapshots[name]) - 1
            else:
                if valid is not None:
                    arr = arr.copy()
                    arr[~valid] = 0   # canonical zero (0 / 0.0 / False)
                out_cols[name] = arr
            if valid is not None:
                out_valid[name] = valid
                self._nullable.add(name)
        self.rows += n
        self._chunks.append(_Chunk(out_cols, out_valid, dictver))

    # -- finalize -------------------------------------------------------- #
    def final_dictionaries(self) -> Dict[str, Dictionary]:
        out: Dict[str, Dictionary] = {}
        for name in self._string_cols:
            d = self._dicts.get(name)
            vals = tuple(str(v) for v in d) if d is not None else ()
            # an all-null string column still needs a non-empty dictionary
            # for code 0 to decode (mirrors build_catalog's convention)
            out[name] = vals if vals else ("",)
        return out

    def _unified_dtypes(self) -> Dict[str, np.dtype]:
        """Per-column dtype across all chunks; int/float mixes widen to
        float64 (CSV fallback lane type promotion)."""
        dtypes: Dict[str, np.dtype] = {}
        for ch in self._chunks:
            for name, arr in ch.cols.items():
                d = dtypes.get(name)
                if d is None:
                    dtypes[name] = arr.dtype
                elif d != arr.dtype:
                    if (np.issubdtype(d, np.number)
                            and np.issubdtype(arr.dtype, np.number)):
                        dtypes[name] = np.result_type(d, arr.dtype)
                    else:
                        raise TypeError(
                            f"column {name!r} changes type across batches "
                            f"({d} vs {arr.dtype}); files of one read must "
                            f"share a schema")
        return dtypes

    def finalize(self) -> SpillTable:
        """Recode stale chunks onto the final dictionaries, materialize
        validity masks, and append everything round-robin into a
        ``SpillTable``.  The builder is spent afterwards."""
        dicts = self.final_dictionaries()
        spill = SpillTable(self.parallelism, dictionaries=dicts)
        if not self._chunks:
            return spill
        dtypes = self._unified_dtypes()
        final_ver = {name: len(self._snapshots[name]) - 1
                     for name in self._string_cols if name in self._snapshots}
        for i, ch in enumerate(self._chunks):
            rank = i % self.parallelism
            cols: Dict[str, np.ndarray] = {}
            for name in self._names:
                arr = ch.cols[name]
                if name in self._string_cols:
                    ver = ch.dictver.get(name, 0)
                    if ver != final_ver.get(name, 0):
                        old = self._snapshots[name][ver]
                        if old:   # empty snapshot = all-null chunk, codes 0
                            arr = recode_mapping(old, dicts[name])[arr]
                            valid = ch.valid.get(name)
                            if valid is not None:
                                arr[~valid] = 0   # remap moved the null fill
                            self.recodes += 1
                    arr = arr.astype(CODE_DTYPE, copy=False)
                elif arr.dtype != dtypes[name]:
                    arr = arr.astype(dtypes[name])
                cols[name] = arr
            n = len(next(iter(cols.values())))
            for name in sorted(self._nullable):
                valid = ch.valid.get(name)
                cols[mask_name(name)] = (np.ones((n,), bool)
                                         if valid is None else valid)
            spill.append(rank, cols)
        self._chunks = []
        return spill

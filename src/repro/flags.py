"""Process-wide build/run flags.

Two families live here:

* **Trace-time build flags** (cost-accounting controls for the dry-run).
  XLA's ``HloCostAnalysis`` counts a while-loop body ONCE (no trip-count
  multiplication), so scanned programs under-report flops/bytes/collectives.
  The dry-run therefore lowers *counting builds* with every scan unrolled at
  one and two periods of depth and extrapolates per-period costs (see
  ``launch/dryrun.py``).  These flags switch the scans to unrolled form at
  trace time; production/training builds leave them off.

* **Fault injection** (``repro.faults``).  ``FLAGS.faults`` holds a fault
  plan string (``site[@occ][xN]=kind;...``); when unset, the ``REPRO_FAULTS``
  env var is consulted.  ``fault_injection(...)`` scopes a plan; the
  executors resolve the active plan via ``repro.faults.resolve_faults``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Optional


@dataclasses.dataclass
class _Flags:
    unroll_layers: bool = False   # layer-period scan -> unrolled
    unroll_inner: bool = False    # CE chunks + attention kv blocks -> unrolled
    faults: Optional[str] = None  # fault plan string; None -> $REPRO_FAULTS


FLAGS = _Flags()


def fault_spec() -> Optional[str]:
    """The active fault plan string: ``FLAGS.faults`` if set, else the
    ``REPRO_FAULTS`` env var ("" / "0" mean off)."""
    if FLAGS.faults is not None:
        return FLAGS.faults or None
    spec = os.environ.get("REPRO_FAULTS", "")
    return spec if spec not in ("", "0") else None


@contextlib.contextmanager
def fault_injection(spec: str):
    """Scope a fault plan string: every execution inside the block resolves
    it (unless an explicit ``faults=`` argument overrides)."""
    old = FLAGS.faults
    FLAGS.faults = spec
    try:
        yield
    finally:
        FLAGS.faults = old


@contextlib.contextmanager
def unrolled_scans(layers: bool = True, inner: bool = True):
    old = (FLAGS.unroll_layers, FLAGS.unroll_inner)
    FLAGS.unroll_layers, FLAGS.unroll_inner = layers, inner
    try:
        yield
    finally:
        FLAGS.unroll_layers, FLAGS.unroll_inner = old


def scan_unroll_layers() -> int:
    return True if FLAGS.unroll_layers else 1


def scan_unroll_inner() -> int:
    return True if FLAGS.unroll_inner else 1

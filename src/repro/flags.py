"""Trace-time build flags (cost-accounting controls for the dry-run).

XLA's ``HloCostAnalysis`` counts a while-loop body ONCE (no trip-count
multiplication), so scanned programs under-report flops/bytes/collectives.
The dry-run therefore lowers *counting builds* with every scan unrolled at
one and two periods of depth and extrapolates per-period costs (see
``launch/dryrun.py``).  These flags switch the scans to unrolled form at
trace time; production/training builds leave them off.
"""

from __future__ import annotations

import contextlib
import dataclasses


@dataclasses.dataclass
class _Flags:
    unroll_layers: bool = False   # layer-period scan -> unrolled
    unroll_inner: bool = False    # CE chunks + attention kv blocks -> unrolled


FLAGS = _Flags()


@contextlib.contextmanager
def unrolled_scans(layers: bool = True, inner: bool = True):
    old = (FLAGS.unroll_layers, FLAGS.unroll_inner)
    FLAGS.unroll_layers, FLAGS.unroll_inner = layers, inner
    try:
        yield
    finally:
        FLAGS.unroll_layers, FLAGS.unroll_inner = old


def scan_unroll_layers() -> int:
    return True if FLAGS.unroll_layers else 1


def scan_unroll_inner() -> int:
    return True if FLAGS.unroll_inner else 1

"""Data layer: synthetic corpus + DDF preprocessing -> training batches."""

from .pipeline import (CorpusConfig, batches_from_table, preprocess,
                       source_weights, synth_corpus)

__all__ = ["CorpusConfig", "batches_from_table", "preprocess",
           "source_weights", "synth_corpus"]

"""DDF-powered training-data pipeline (the paper's §IV-C, end to end).

The paper's motivating workflow is *data preprocessing applications feeding
a distributed deep-learning application*, stitched together through the
``CylonStore``.  This module is that workflow in JAX:

  1. a synthetic sharded corpus (document id, quality score, dup-group hash,
     fixed-width token payload) materialized as a ``DistTable``,
  2. a **DDF preprocessing application** executed on a ``CylonExecutor``
     gang under the pseudo-BSP environment:
       dedup      — distributed groupby on the dup-group hash (keep min id),
       filter     — quality threshold (local op, coalesced),
       join       — against a per-source weights table (distributed join),
       balance    — sample-based repartition on document length (§VI skew
                    mitigation: straggler-proof shard sizes),
  3. results ``put`` into a ``CylonStore``; the *training application*
     ``get``s them (repartitioning to its own gang size if different) and
     packs token payloads into (B, S) batches.

Token payloads are vector columns — the Table machinery treats them as a
single (capacity, width) column, so the whole pipeline runs inside one
shard_map program per stage.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp  # noqa: F401  (used inside the BSP program)
import numpy as np

from ..core import CylonExecutor, CylonStore, DevicePool, DistTable
from ..dataframe import (Table, filter_rows, groupby, join, repartition_balanced,
                         shuffle)


@dataclasses.dataclass
class CorpusConfig:
    num_docs: int = 4096
    payload_tokens: int = 128     # tokens carried per document row
    vocab_size: int = 50304
    dup_rate: float = 0.3         # fraction of docs that are duplicates
    num_sources: int = 8
    seed: int = 0


def synth_corpus(cfg: CorpusConfig, parallelism: int,
                 capacity: Optional[int] = None) -> DistTable:
    """Synthetic sharded corpus as a DistTable.

    Shards get 2x capacity headroom by default: hash redistribution moves
    a Poisson-ish share to each rank, and a table filled to exactly its
    capacity is statistically guaranteed to overflow some destination
    bucket (rows dropped-and-counted, but dropped nonetheless).
    """
    rng = np.random.default_rng(cfg.seed)
    n = cfg.num_docs
    if capacity is None:
        per = -(-n // parallelism)
        capacity = max(8, -(-2 * per // 8) * 8)
    uniq = int(n * (1 - cfg.dup_rate))
    dup_group = rng.integers(0, max(uniq, 1), n).astype(np.int32)
    data = {
        "doc_id": np.arange(n, dtype=np.int32),
        "dup_group": dup_group,
        "source": rng.integers(0, cfg.num_sources, n).astype(np.int32),
        "quality": rng.random(n).astype(np.float32),
        "length": rng.integers(cfg.payload_tokens // 2, cfg.payload_tokens,
                               n).astype(np.int32),
        "tokens": rng.integers(0, cfg.vocab_size,
                               (n, cfg.payload_tokens)).astype(np.int32),
    }
    return DistTable.from_numpy(data, parallelism, capacity=capacity)


def source_weights(num_sources: int, parallelism: int) -> DistTable:
    data = {
        "source": np.arange(num_sources, dtype=np.int32),
        "weight": np.linspace(0.5, 1.5, num_sources).astype(np.float32),
    }
    return DistTable.from_numpy(data, parallelism,
                                capacity=max(8, num_sources))


def preprocess(executor: CylonExecutor, corpus: DistTable,
               weights: DistTable, quality_min: float = 0.2,
               store: Optional[CylonStore] = None,
               store_key: str = "train_corpus") -> DistTable:
    """The DDF preprocessing application (one BSP program on the gang)."""

    def app(ctx, docs: Table, wts: Table) -> Table:
        comm = ctx.comm
        # 1. dedup: min doc_id per dup_group, carried via groupby; then join
        #    winners back to recover payloads.
        winners, _ = groupby(docs.select(["dup_group", "doc_id"]), comm,
                             keys=["dup_group"], aggs={"doc_id": ["min"]})
        winners = winners.rename({"doc_id_min": "doc_id"})
        docs2, _, _ = join(docs, winners.select(["doc_id"]), comm,
                           on="doc_id", out_capacity=docs.capacity)
        # 2. quality filter (local, implicitly coalesced with the join tail)
        docs3 = filter_rows(docs2, lambda t: t.col("quality") >= quality_min)
        # 3. join with per-source weights (broadcast-sized right side)
        docs4, _, _ = join(docs3, wts, comm, on="source",
                           out_capacity=docs.capacity)
        # 4. sample-based balance on length (paper §VI skew mitigation).
        #    Low-cardinality keys (a handful of distinct lengths) tie at the
        #    splitters and overflow one destination's capacity bucket — the
        #    classic skew failure the paper's sampling is meant to avoid —
        #    so the sort key gets a unique tie-breaker suffix (doc_id).
        docs4 = docs4.with_column(
            "balance_key",
            docs4.col("length") * jnp.int32(65536)
            + (docs4.col("doc_id") % jnp.int32(65536)))
        docs5, _ = repartition_balanced(docs4, comm, key_col="balance_key",
                                        capacity_factor=4.0)
        return docs5.select([n for n in docs5.column_names
                             if n != "balance_key"])

    out = executor.run_cylon(app, corpus, weights)
    if store is not None:
        store.put(store_key, out)
    return out


def batches_from_table(table: DistTable, batch: int, seq_len: int,
                       seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Pack document payloads into (B, S) token/label batches (host side)."""
    data = table.to_numpy()
    toks = data["tokens"]                      # (N, payload)
    rng = np.random.default_rng(seed)
    flat = toks.reshape(-1)
    need = batch * (seq_len + 1)
    while True:
        start = rng.integers(0, max(len(flat) - need, 1))
        window = flat[start:start + need]
        if len(window) < need:
            window = np.concatenate([window, flat[:need - len(window)]])
        arr = window.reshape(batch, seq_len + 1)
        yield {"tokens": arr[:, :-1].astype(np.int32),
               "labels": arr[:, 1:].astype(np.int32)}
